"""Real-mesh execution parity harness — the correctness spine for
``PimGrid.fit`` running under a real ``jax.sharding.Mesh``
(``core.pim.make_mesh_grid`` -> shard_map hierarchical psums).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI ``tier-1-multidevice`` job) and pins every workload x plan cell
against two oracles:

  * the python per-step engine ON THE SAME MESH — bit-exact for every
    cell (same collectives, same order of operations, so any drift is
    an engine bug, not float noise),
  * the emulated vmap grid (``make_cpu_grid``) — tight allclose for
    exact wires (the only difference is psum association order) and
    oracle-bounded for compressed wires: at hop size 2 each pod
    quantizes its half independently, so the summed wire legitimately
    differs from quantizing the total; error feedback keeps the
    trajectory O(1) from the exact one, which is the bound asserted.

Also pinned here: integer-leaf bit-exactness across grids (int32 psum
is associative), the error-feedback buffer's hop layout + ``P("pod")``
sharding and its continuation across split fits, buffer-donation
markers in the lowered runner HLO, Trainer checkpoint round-trips of
mesh ``merge_state`` (EF + momentum + tuning trace), and the
``merge_plan="auto"`` DCN pricing flip (``CostModel.n_chips``: the
compressed wire wins the modeled merge on an 8-chip mesh and loses on
the single-chip emulation).

On a single-device runtime everything mesh-shaped is skipped; the
construction smoke tests still run (a (1, 1) mesh is legal).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.pim_ml import PimMLConfig
from repro.core import datasets
from repro.core.mlalgos import api, make_linreg_step
from repro.core.pim import make_cpu_grid, make_mesh_grid
from repro.distributed import merge_plan as mp
from repro.distributed.compression import CompressionConfig
from repro.distributed.merge_plan import MergePlan, SlowMo
from repro.launch.mesh import make_pim_mesh
from repro.runtime import Trainer, TrainerConfig
from repro.tuning.cost import CostModel

KEY = jax.random.PRNGKey(0)
MULTI = len(jax.devices()) >= 8
multidevice = pytest.mark.skipif(
    not MULTI,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

N_VDPUS = 16
STEPS = 16
INT8 = CompressionConfig(bits=8)
TOPK = CompressionConfig(bits=8, top_k_frac=0.25)

PLAN_CELLS = {
    "exact_k1": MergePlan(),
    "exact_k4": MergePlan(cadence=4),
    "int8_k1": MergePlan(compression=INT8),
    "int8_k4": MergePlan(cadence=4, compression=INT8),
    "topk_k4": MergePlan(cadence=4, compression=TOPK),
    "overlap_k1": MergePlan(overlap=True),
    "overlap_k4": MergePlan(cadence=4, overlap=True),
    "overlap_int8_k4": MergePlan(cadence=4, overlap=True,
                                 compression=INT8),
    "slowmo_k4": MergePlan(cadence=4, outer=SlowMo(beta=0.5)),
}
# Exact wires differ from the vmap grid only by psum association order;
# compressed wires re-grid per pod half (see module docstring), so they
# get the loose bound plus the stay-near-exact oracle below.
EXACT_CELLS = {"exact_k1", "exact_k4", "overlap_k1", "overlap_k4",
               "slowmo_k4"}
# compressed cell -> the exact cell whose trajectory EF must track
EF_ORACLE = {"int8_k1": "exact_k1", "int8_k4": "exact_k4",
             "topk_k4": "exact_k4", "overlap_int8_k4": "overlap_k4"}
# top-k keeps each pod's LOCAL largest entries — a different survivor
# set than the emulation's global top-k, so its cross-grid drift is
# larger than pure quantization's (EF still bounds it); against the
# EXACT trajectory it is looser still: at frac=0.25 and 4 merge rounds
# most of the dropped mass is still parked in the EF buffer
CELL_TOL = {"topk_k4": 0.1}
ORACLE_TOL = {"topk_k4": 0.25}


@functools.lru_cache(maxsize=None)
def _grid(kind):
    if kind == "mesh":
        return make_mesh_grid(N_VDPUS, pods=2)
    return make_cpu_grid(N_VDPUS)


@functools.lru_cache(maxsize=None)
def _linreg(kind):
    grid = _grid(kind)
    X, y, _ = datasets.regression(KEY, 192, 6)
    data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)
    return grid, data, lf, uf, w0


@functools.lru_cache(maxsize=None)
def _cell_run(kind, cell, engine):
    grid, data, lf, uf, w0 = _linreg(kind)
    w, hist = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                       data=data, steps=STEPS, engine=engine,
                       scan_chunk=4, merge_plan=PLAN_CELLS[cell])
    losses = np.asarray([float(h["loss"]) for h in hist])
    return np.asarray(w), losses


# ---------------------------------------------------------------------------
# construction (runs at any device count)
# ---------------------------------------------------------------------------


class TestMeshGridConstruction:
    def test_pods_must_divide_devices(self):
        with pytest.raises(ValueError, match="divide"):
            make_pim_mesh(len(jax.devices()) + 1)

    def test_make_mesh_grid_shards_and_reduces(self):
        grid = make_mesh_grid(8)
        assert grid.data_axes == ("pod", "data")
        assert tuple(grid.mesh.axis_names) == ("pod", "data")
        data, _ = grid.shard_rows(jnp.arange(16.0)[:, None])
        out = grid.map_reduce(
            lambda _, sl: {"s": jnp.sum(sl["X"] * sl["w"][:, None])},
            None, data)
        assert float(out["s"]) == 120.0

    @multidevice
    def test_eight_devices_eight_shards(self):
        grid = _grid("mesh")
        assert grid.n_shards == 8
        assert grid.mesh.shape["pod"] == 2

    @multidevice
    def test_vdpus_must_divide_shards(self):
        with pytest.raises(ValueError, match="divisible"):
            make_mesh_grid(6, pods=2)


# ---------------------------------------------------------------------------
# the plan-cell parity matrix (the tentpole's spine)
# ---------------------------------------------------------------------------


@multidevice
class TestPlanCellParity:
    @pytest.mark.parametrize("cell", sorted(PLAN_CELLS))
    def test_scan_matches_python_on_mesh(self, cell):
        """The compiled scan engine and the per-step python oracle run
        the same mesh collectives — bit-exact, every cell."""
        w_scan, h_scan = _cell_run("mesh", cell, "scan")
        w_py, h_py = _cell_run("mesh", cell, "python")
        np.testing.assert_array_equal(w_scan, w_py)
        np.testing.assert_array_equal(h_scan, h_py)

    @pytest.mark.parametrize("cell", sorted(PLAN_CELLS))
    def test_mesh_matches_vmap_grid(self, cell):
        w_mesh, h_mesh = _cell_run("mesh", cell, "scan")
        w_vmap, h_vmap = _cell_run("vmap", cell, "scan")
        tol = 1e-6 if cell in EXACT_CELLS else CELL_TOL.get(cell, 2e-2)
        np.testing.assert_allclose(w_mesh, w_vmap, rtol=0, atol=tol)
        assert h_mesh.shape == h_vmap.shape == (STEPS,)

    @pytest.mark.parametrize("cell", sorted(EF_ORACLE))
    def test_compressed_cells_stay_near_exact(self, cell):
        """Error feedback bounds the compressed mesh trajectory O(1)
        from the exact one — the oracle for cells whose wire cannot
        match the single-hop emulation bit-for-bit."""
        w_c, _ = _cell_run("mesh", cell, "scan")
        w_e, _ = _cell_run("mesh", EF_ORACLE[cell], "scan")
        np.testing.assert_allclose(w_c, w_e, rtol=0,
                                   atol=ORACLE_TOL.get(cell, 0.05))


# ---------------------------------------------------------------------------
# every workload through the canonical entry point
# ---------------------------------------------------------------------------


def _workload_case(name):
    cfg = PimMLConfig(workload=name)
    if name == "linreg":
        X, y, _ = datasets.regression(KEY, 256, 6)
    elif name in ("logreg", "svm"):
        X, y, _ = datasets.binary_classification(KEY, 256, 6)
    elif name == "multinomial":
        X, y = datasets.mixture_classification(KEY, 256, 6,
                                               cfg.mn_classes)
    elif name == "kmeans":
        X, _, _ = datasets.blobs(KEY, 256, 4, k=cfg.km_clusters,
                                 spread=0.3)
        y = None
    else:
        X, y = datasets.mixture_classification(KEY, 256, 6,
                                               cfg.dt_classes)
    return cfg.workload_spec(), X, y


@multidevice
class TestWorkloadParity:
    WORKLOADS = ("linreg", "logreg", "svm", "multinomial", "kmeans",
                 "dtree")

    @pytest.mark.parametrize("name", WORKLOADS)
    def test_mesh_matches_vmap(self, name):
        wl, X, y = _workload_case(name)
        evals = {}
        for kind in ("mesh", "vmap"):
            res = api.fit(wl, _grid(kind), X, y, steps=8)
            evals[kind] = res.eval(X, y)
        assert evals["mesh"].keys() == evals["vmap"].keys()
        for k in evals["mesh"]:
            np.testing.assert_allclose(
                np.asarray(evals["mesh"][k]),
                np.asarray(evals["vmap"][k]),
                rtol=1e-5, atol=1e-6, err_msg=f"{name}:{k}")

    @pytest.mark.parametrize("name", ("svm", "multinomial"))
    def test_plan_cells_on_mesh(self, name):
        """The PR 5 workloads ride the full plan surface on the mesh:
        compressed + outer cells converge within the EF bound of the
        exact cell."""
        wl, X, y = _workload_case(name)
        grid = _grid("mesh")
        exact = api.fit(wl, grid, X, y, steps=16,
                        merge_plan=MergePlan(cadence=4))
        acc_exact = float(exact.eval(X, y)["accuracy"])

        # int8: error feedback keeps the state itself near exact
        int8 = api.fit(wl, grid, X, y, steps=16,
                       merge_plan=MergePlan(cadence=4,
                                            compression=INT8))
        for leaf_c, leaf_e in zip(jax.tree.leaves(int8.state),
                                  jax.tree.leaves(exact.state)):
            np.testing.assert_allclose(
                np.asarray(leaf_c), np.asarray(leaf_e),
                rtol=0, atol=0.25)

        # slowmo: a *different* optimizer (outer momentum moves ~2x per
        # round), so the contract is convergence quality, not weights
        slowmo = api.fit(wl, grid, X, y, steps=16,
                         merge_plan=MergePlan(cadence=4,
                                              outer=SlowMo(beta=0.5)))
        acc_slowmo = float(slowmo.eval(X, y)["accuracy"])
        assert acc_slowmo >= acc_exact - 0.15


# ---------------------------------------------------------------------------
# integer leaves: the wire's int passthrough is associative, so mesh
# and vmap must agree bit-for-bit even under a compressed plan
# ---------------------------------------------------------------------------


@multidevice
class TestIntegerLeafExactness:
    def _fit(self, grid):
        def local_fn(state, sl):
            pos = (sl["X"] > 0.0) * sl["w"][:, None]
            return {"hist": jnp.sum(pos.astype(jnp.int32), axis=0),
                    "mass": jnp.sum(sl["X"] * sl["w"][:, None], axis=0)}

        def update_fn(state, merged):
            state = {"hist": state["hist"] + merged["hist"],
                     "w": state["w"] - 1e-3 * merged["mass"]}
            return state, {"hist": merged["hist"]}

        X = jax.random.normal(KEY, (96, 5))
        data, _ = grid.shard_rows(X)
        s0 = {"hist": jnp.zeros((5,), jnp.int32),
              "w": jnp.zeros((5,), jnp.float32)}
        state, hist = grid.fit(
            init_state=s0, local_fn=local_fn, update_fn=update_fn,
            data=data, steps=4, merge_plan=MergePlan(compression=INT8))
        return (np.asarray(state["hist"]),
                np.asarray([h["hist"] for h in hist]))

    def test_int32_partials_bit_exact_across_grids(self):
        h_mesh, hist_mesh = self._fit(_grid("mesh"))
        h_vmap, hist_vmap = self._fit(_grid("vmap"))
        np.testing.assert_array_equal(h_mesh, h_vmap)
        np.testing.assert_array_equal(hist_mesh, hist_vmap)


# ---------------------------------------------------------------------------
# merge_state plumbing on the mesh
# ---------------------------------------------------------------------------


@multidevice
class TestMergeStateOnMesh:
    def test_ef_buffer_hop_layout_and_sharding(self):
        grid, data, lf, uf, w0 = _linreg("mesh")
        holder = {}
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=8, merge_plan=PLAN_CELLS["int8_k4"],
                 merge_state=holder)
        for leaf in jax.tree.leaves(holder["error"]):
            assert leaf.shape[0] == 2          # one slice per pod
            assert leaf.sharding.spec == P("pod")

    def test_ef_continues_across_fit_calls(self):
        grid, data, lf, uf, w0 = _linreg("mesh")
        w_one, _ = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                            data=data, steps=16,
                            merge_plan=PLAN_CELLS["int8_k4"])
        holder = {}
        w_half, _ = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                             data=data, steps=8,
                             merge_plan=PLAN_CELLS["int8_k4"],
                             merge_state=holder)
        w_two, _ = grid.fit(init_state=w_half, local_fn=lf,
                            update_fn=uf, data=data, steps=8,
                            merge_plan=PLAN_CELLS["int8_k4"],
                            merge_state=holder)
        np.testing.assert_allclose(np.asarray(w_two),
                                   np.asarray(w_one),
                                   rtol=1e-6, atol=1e-7)

    def test_slowmo_momentum_in_holder(self):
        grid, data, lf, uf, w0 = _linreg("mesh")
        holder = {}
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=8, merge_plan=PLAN_CELLS["slowmo_k4"],
                 merge_state=holder)
        assert "momentum" in holder
        # the OptState pytree carries a state-shaped momentum leaf
        # (plus optimizer scalars like the step count)
        shapes = [leaf.shape for leaf in
                  jax.tree.leaves(holder["momentum"])]
        assert w0.shape in shapes


# ---------------------------------------------------------------------------
# buffer donation: the scan carry must be marked donatable in the
# lowered HLO on donating backends, and unmarked on CPU
# ---------------------------------------------------------------------------


@multidevice
class TestDonation:
    def _pieces(self):
        # a FRESH grid per test: make_runner caches per grid, and the
        # donation decision is baked in at trace time
        grid = make_mesh_grid(N_VDPUS, pods=2)
        X, y, _ = datasets.regression(KEY, 192, 6)
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)
        return grid, data, lf, uf, w0

    def test_donating_backend_marks_carry(self, monkeypatch):
        grid, data, lf, uf, w0 = self._pieces()
        monkeypatch.setattr(mp, "donating_backend", lambda: True)
        runner = grid.make_runner(lf, uf)
        text = runner.lower(w0, data, length=2).as_text()
        assert "jax.buffer_donor" in text

    def test_cpu_backend_does_not_mark_carry(self):
        grid, data, lf, uf, w0 = self._pieces()
        assert not mp.donating_backend()
        runner = grid.make_runner(lf, uf)
        text = runner.lower(w0, data, length=2).as_text()
        assert "jax.buffer_donor" not in text


# ---------------------------------------------------------------------------
# auto plan: the controller prices the DCN wire on a mesh
# ---------------------------------------------------------------------------


@multidevice
class TestAutoPlanOnMesh:
    def test_auto_fit_runs_and_traces(self):
        grid, data, lf, uf, w0 = _linreg("mesh")
        holder = {}
        w, hist = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                           data=data, steps=16, merge_plan="auto",
                           merge_state=holder)
        assert len(hist) == 16
        trace = holder["tuning_trace"]
        assert trace["choices"]                 # candidates considered
        assert trace["cost_table"]              # roofline-priced rows

    def test_n_chips_tracks_mesh(self):
        for kind, expect in (("mesh", 8), ("vmap", 1)):
            grid, data, lf, uf, w0 = _linreg(kind)
            model = CostModel.for_fit(grid, lf, uf, w0, data)
            assert model.n_chips == expect

    def _big_model(self, kind):
        # a wire large enough (256 KiB) that the slow-hop pricing
        # dominates the prediction either way
        grid = _grid(kind)
        data, _ = grid.shard_rows(jnp.zeros((32, 4)))
        w0 = jnp.zeros((1 << 16,), jnp.float32)

        def lf(w, sl):
            return {"g": w * jnp.sum(sl["w"])}

        def uf(w, merged):
            return w - 1e-3 * merged["g"], {"m": merged["g"][0]}

        return CostModel.for_fit(grid, lf, uf, w0, data)

    def test_dcn_pricing_flips_the_compression_verdict(self):
        """The CostModel wire_bw branch: on the 8-chip mesh the slow
        hop crosses DCN, so the int8 wire's byte saving beats its
        encode cost; on the single-chip emulation the same hop moves at
        HBM speed and compression can never win the modeled merge."""
        mesh = self._big_model("mesh")
        vmap = self._big_model("vmap")
        assert mesh.predict(cadence=4, compression=INT8)["t_merge_s"] \
            < mesh.predict(cadence=4)["t_merge_s"]
        assert vmap.predict(cadence=4, compression=INT8)["t_merge_s"] \
            > vmap.predict(cadence=4)["t_merge_s"]


# ---------------------------------------------------------------------------
# Trainer checkpoint round-trip of mesh merge_state
# ---------------------------------------------------------------------------


@multidevice
class TestTrainerMeshCheckpoint:
    def _mesh_holder(self):
        """A holder populated by real mesh fits: sharded hop-shaped EF
        + SlowMo momentum + the auto controller's tuning trace."""
        grid, data, lf, uf, w0 = _linreg("mesh")
        holder = {}
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=8,
                 merge_plan=MergePlan(cadence=4, compression=INT8,
                                      outer=SlowMo(beta=0.5)),
                 merge_state=holder)
        auto = {}
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=16, merge_plan="auto", merge_state=auto)
        holder["tuning_trace"] = auto["tuning_trace"]
        return holder

    def _trainer(self, tmp_path, holder):
        def step_fn(state, batch):
            w = state["w"] - 0.1 * batch["g"]
            return {"w": w}, {"loss": jnp.sum(w ** 2)}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                            log_every=100, merge_compression=INT8)
        return Trainer(step_fn, {"w": jnp.ones((3,))},
                       lambda s: {"g": jnp.ones((3,))}, cfg,
                       merge_state=holder)

    def test_mesh_merge_state_round_trips(self, tmp_path):
        holder = self._mesh_holder()
        tr = self._trainer(tmp_path, holder)
        tr.run(8)

        # resume: the fresh holder is seeded with zeroed templates for
        # the array buffers; the trace needs no seeding (it rides the
        # checkpoint manifest as JSON)
        holder2 = {
            "error": jax.tree.map(jnp.zeros_like, holder["error"]),
            "momentum": jax.tree.map(jnp.zeros_like,
                                     holder["momentum"]),
        }
        tr2 = self._trainer(tmp_path, holder2)
        assert tr2.start_step == 8
        for got, want in zip(jax.tree.leaves(holder2["error"]),
                             jax.tree.leaves(holder["error"])):
            assert got.shape[0] == 2           # hop layout survives
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(want))
        for got, want in zip(jax.tree.leaves(holder2["momentum"]),
                             jax.tree.leaves(holder["momentum"])):
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(want))
        assert holder2["tuning_trace"] == holder["tuning_trace"]
