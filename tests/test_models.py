"""Per-arch smoke tests (reduced same-family configs): one forward/train
step on CPU asserting shapes + no NaNs, plus decode/forward consistency
for every recurrence family and MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs, get_config
from repro.models import build
from repro.models import transformer as tfm

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list_archs()


def _batch_for(cfg, B=2, S=16):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_ctx, cfg.d_model))
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_embeds, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads, _ = jax.grad(model.loss, has_aux=True)(params, batch)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(KEY)
    B = 2
    cache = model.init_cache(B, 32)
    if cfg.encoder is not None:
        from repro.models import encdec as ed
        frames = jax.random.normal(KEY, (B, cfg.encoder.n_ctx,
                                         cfg.d_model))
        cache = ed.encdec_build_cross(cfg, params, frames, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok,
                                                jnp.int32(0))
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m",
                                  "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """KV-cache / SSM-state / LRU-state decode == full forward."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full, _ = tfm.lm_forward(cfg, params, toks)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_sliding_window_ring_buffer():
    """Positions beyond the window must not influence local attention."""
    cfg = get_smoke_config("recurrentgemma-2b")
    model = build(cfg)
    params = model.init(KEY)
    B, S = 1, 16   # window is 8 in the smoke config
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
    # ring caches must have window-sized seq dims
    k_shapes = [l.shape for l in jax.tree.leaves(cache)
                if l.ndim >= 4]
    assert any(s[-3] == cfg.window for s in k_shapes)


def test_moe_routing_conservation():
    """Top-k gate weights are renormalized and outputs are finite; with
    capacity_factor >= n_experts every token must be routed."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=99.0))
    p = moe_mod.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    y, aux = moe_mod.moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0
    # aux ~ 1 means balanced; must be within [1, n_experts]
    assert 0.5 <= float(aux) <= cfg.moe.n_experts + 1e-3


def test_scan_groups_periodic_detection():
    cfg = get_config("recurrentgemma-2b")
    unit, reps, tail = cfg.scan_groups()
    assert unit == ("rglru", "rglru", "local_attn")
    assert reps == 8 and tail == ("rglru", "rglru")
    cfg2 = get_config("qwen2-0.5b")
    unit2, reps2, tail2 = cfg2.scan_groups()
    assert unit2 == ("attn",) and reps2 == 24 and tail2 == ()


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    want = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (L, d, H, kv, ff, V) in want.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V), arch
    # MoE structure
    q3 = get_config("qwen3-moe-235b-a22b")
    assert q3.moe.n_experts == 128 and q3.moe.top_k == 8
    p35 = get_config("phi3.5-moe-42b-a6.6b")
    assert p35.moe.n_experts == 16 and p35.moe.top_k == 2
    rg = get_config("recurrentgemma-2b")
    assert rg.window == 2048
    m2 = get_config("mamba2-370m")
    assert m2.ssm.d_state == 128
