"""Distribution substrate on a 1-device mesh: collectives semantics,
compression error bounds, overlap engine equivalence, sharding tables,
and the roofline HLO parser against a known module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.distributed import collectives as coll
from repro.distributed import compression as comp
from repro.distributed.overlap import microbatched_grads
from repro.distributed.sharding import (LogicalRules, make_rules,
                                        shard_hint, use_rules)
from repro.launch import shardings as sh
from repro.roofline import analysis as ra


MESH = make_host_mesh(1, 1)


def _in_mesh(fn, *args):
    return shard_map(fn, mesh=MESH, in_specs=P(), out_specs=P(),
                     check_rep=False)(*args)


class TestCollectives:
    def test_quantized_psum_identity_single_shard(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64,))
        out = _in_mesh(lambda v: coll.quantized_psum(v, "data", bits=8), x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                                   atol=float(jnp.max(jnp.abs(x))) / 100)

    def test_quantized_psum_ef_residual(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64,))
        e0 = jnp.zeros_like(x)

        def body(v, e):
            return coll.quantized_psum_ef(v, e, "data", bits=8)

        out, err = shard_map(body, mesh=MESH, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_rep=False)(x, e0)
        np.testing.assert_allclose(np.asarray(out + err), np.asarray(x),
                                   atol=1e-6)

    def test_hierarchical_psum_single(self):
        x = jnp.ones((4,))
        out = _in_mesh(
            lambda v: coll.hierarchical_psum(v, ["data"], None), x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


class TestCompression:
    def test_compressed_reduce_exact_axis(self):
        grads = {"a": jnp.ones((8,)), "b": jnp.full((3,), -2.0)}
        err = comp.init_error_state(grads)
        cfg = comp.CompressionConfig(slow_axis=None, fast_axes=("data",))

        def body(g, e):
            return comp.compressed_reduce(g, e, cfg)

        specs = jax.tree.map(lambda _: P(), grads)
        out, _ = shard_map(body, mesh=MESH, in_specs=(specs, specs),
                           out_specs=(specs, specs),
                           check_rep=False)(grads, err)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(8))

    def test_topk_sparsify(self):
        g = jnp.asarray([0.1, -5.0, 0.2, 3.0])
        kept, err = comp.topk_sparsify(g, 0.5, jnp.zeros_like(g))
        assert float(kept[1]) == -5.0 and float(kept[3]) == 3.0
        assert float(kept[0]) == 0.0
        np.testing.assert_allclose(np.asarray(kept + err), np.asarray(g))


class TestOverlap:
    def test_microbatched_equals_full_batch(self):
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (4,))}
        batch = {"x": jax.random.normal(key, (8, 4)),
                 "y": jax.random.normal(key, (8,))}

        def loss_fn(p, b):
            r = b["x"] @ p["w"] - b["y"]
            return jnp.mean(r ** 2), {}

        loss_f, grads_f = jax.value_and_grad(
            lambda p: loss_fn(p, batch)[0])(params), None
        full_loss, full_grads = loss_f[0], jax.grad(
            lambda p: loss_fn(p, batch)[0])(params)
        mb_loss, mb_grads, _ = microbatched_grads(
            loss_fn, params, batch, n_micro=4)
        np.testing.assert_allclose(float(mb_loss), float(full_loss),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(mb_grads["w"]),
                                   np.asarray(full_grads["w"]), rtol=1e-4)


class TestShardingTables:
    def test_rules_dedup_mesh_axes(self):
        rules = make_rules(MESH, n_heads=4, n_kv_heads=2)
        spec = rules.spec("batch", "kv_seq", "kv_heads")
        # "model" claimed once: kv_seq wins, kv_heads replicated
        assert spec[1] == "model" and spec[2] is None

    def test_head_divisibility_fallback(self):
        rules = make_rules(MESH, n_heads=14, n_kv_heads=2)
        # model axis size 1 -> everything divisible; with size-16 mesh the
        # table computed at make_rules time drops heads for 14H
        from repro.distributed.sharding import make_rules as mk
        # emulate a 16-wide model axis table decision
        assert rules.table["heads"] in ("model", None)

    def test_shard_hint_truncates_extra_logical_axes(self):
        """A hint naming more logical axes than the array has dims must
        drop the extras, not pass an over-long PartitionSpec to
        with_sharding_constraint (which rejects any spec longer than
        the array's rank, even on a 1-device mesh) — the decode paths
        hint 4 axes onto arrays that are 2-D/3-D in some shapes."""
        rules = make_rules(MESH, n_heads=4, n_kv_heads=2)
        x = jnp.zeros((4, 8))
        with use_rules(rules):
            y = shard_hint(x, "batch", "seq", "heads", "head_dim")
        assert y.shape == x.shape

    def test_shard_hint_nondivisible_dim_replicates(self):
        rules = make_rules(MESH, n_heads=4, n_kv_heads=2)
        # odd dims can't split over any >1 mesh axis; on the 1-wide
        # host mesh everything divides — the contract is "no crash,
        # shape preserved" either way
        with use_rules(rules):
            y = shard_hint(jnp.zeros((3, 5, 7)), "batch", "seq", "ff")
        assert y.shape == (3, 5, 7)

    def test_param_axes_mapping(self):
        import jax.tree_util as jtu
        tree = {"stack": {"scan": ({"mixer": {
            "wq": jax.ShapeDtypeStruct((2, 8, 4, 2), jnp.float32)}},)}}
        flat, _ = jtu.tree_flatten_with_path(tree)
        axes = sh.param_axes(*flat[0])
        assert axes == (None, "embed", "heads", None)

    def test_moe_param_axes(self):
        import jax.tree_util as jtu
        tree = {"moe": {"w_gate": jax.ShapeDtypeStruct((4, 8, 16),
                                                       jnp.float32)}}
        flat, _ = jtu.tree_flatten_with_path(tree)
        assert sh.param_axes(*flat[0]) == ("experts", "embed", "ff")


class TestRooflineParser:
    def test_counts_scanned_dots(self):
        L, M, K, N = 5, 8, 16, 8

        def step(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(y)

        ws = jnp.zeros((L, K, K), jnp.float32)
        x = jnp.zeros((M, K), jnp.float32)
        comp = jax.jit(step).lower(x, ws).compile()
        parsed = ra.analyze_hlo(comp.as_text())
        want = 2.0 * M * K * K * L
        assert parsed.dot_flops == pytest.approx(want, rel=0.01)
        assert L in parsed.while_trips.values()

    def test_shape_bytes(self):
        assert ra._shape_bytes("bf16[4,8]{1,0}") == 64
        assert ra._shape_bytes("(f32[2,2]{1,0}, s8[4]{0})") == 20

    def test_model_flops_sane(self):
        from repro.configs import get_config
        cfg = get_config("qwen2-0.5b")
        mf = ra.model_flops(cfg, "train", 256, 4096)
        # 6 * ~0.36B active * 1M tokens ~ 2.2e15
        assert 1e15 < mf["param_flops"] < 4e15
        assert mf["n_active_params"] < mf["n_total_params"]
