"""The fault-tolerant training runtime (``repro.resilience``):
deterministic FaultPlan schedules and injectors, survivor-weighted
merges, the recovery ladder (backoff -> rollback -> degradation),
crash-consistent checkpoints (checksums, quarantine, torn writes), the
armed-but-idle parity contract, and the full fault-matrix — every fault
class against every wire format, with replayable recovery traces."""

import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import LinReg
from repro.core.mlalgos.linreg import closed_form, make_linreg_step
from repro.distributed.compression import CompressionConfig
from repro.distributed.merge_plan import MergeFallbackWarning, MergePlan
from repro.resilience import (DispatchTimeout, FaultEvent, FaultPlan,
                              RecoveryPolicy, drive_fit, faults,
                              replay_trace)
from repro.resilience.recovery import DivergenceDetector
from repro.runtime import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)
MULTI = len(jax.devices()) >= 8
multidevice = pytest.mark.skipif(
    not MULTI,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

WIRES = {
    "exact": None,
    "int8ef": CompressionConfig(bits=8, error_feedback=True),
    "topk": CompressionConfig(bits=8, error_feedback=True,
                              top_k_frac=0.25),
}


def _problem(n_vdpus=8, rows=256, feats=6, lr=0.1):
    X, y, _ = datasets.regression(KEY, rows, feats)
    grid = make_cpu_grid(n_vdpus)
    data, n, local_fn, update_fn, w0 = make_linreg_step(
        grid, X, y, lr=lr)
    return grid, X, y, data, local_fn, update_fn, w0


def _err(w, X, y):
    return float(jnp.linalg.norm(jnp.asarray(w) - closed_form(X, y)))


class TestFaultPlan:
    def test_generate_is_deterministic(self):
        kw = dict(rounds=40, n_lanes=8, pods=2,
                  rates={k: 0.1 for k in faults.FAULT_KINDS})
        a = FaultPlan.generate(seed=3, **kw)
        b = FaultPlan.generate(seed=3, **kw)
        assert a == b and hash(a) == hash(b)
        assert a != FaultPlan.generate(seed=4, **kw)

    def test_generated_events_in_bounds(self):
        p = FaultPlan.generate(
            seed=11, rounds=200, n_lanes=8, pods=2,
            rates={k: 0.2 for k in faults.FAULT_KINDS})
        assert p.events            # 200 rounds at 20% each: non-empty
        for e in p.events:
            assert e.kind in faults.FAULT_KINDS
            assert 0 <= e.round < 200
            if e.kind in ("nan_lane", "dead_lane"):
                assert 0 <= e.lane < 8
            if e.kind == "dead_pod":
                assert 0 <= e.pod < 2
            if e.kind == "wire_bitflip":
                assert 23 <= e.bit <= 30
            if e.kind == "timeout":
                assert 0.0 <= e.duration_s <= 0.01

    def test_event_validation(self):
        with pytest.raises(ValueError, match="kind"):
            FaultEvent(0, "meteor_strike")
        with pytest.raises(ValueError, match="round"):
            FaultEvent(-1, "nan_lane")

    def test_queries(self):
        p = FaultPlan(events=(
            FaultEvent(2, "nan_lane", lane=1),
            FaultEvent(5, "timeout"),
            FaultEvent(1, "torn_ckpt"),
        ))
        assert [e.kind for e in p.events_at(2)] == ["nan_lane"]
        assert p.events_at(1) == ()          # torn is save-indexed
        assert [e.kind for e in p.saves_at(1)] == ["torn_ckpt"]
        assert p.next_event_round(0) == 2
        assert p.next_event_round(3) == 5
        assert p.next_event_round(6) is None
        cleared = p.clear_between(0, 6)
        assert cleared.next_event_round(0) is None
        assert cleared.saves_at(1)           # torn events survive

    def test_armed_contextmanager_restores(self):
        outer_p = FaultPlan(seed=1)
        inner_p = FaultPlan(seed=2)
        assert faults.active() is None
        with faults.armed(outer_p):
            assert faults.active() is outer_p
            with faults.armed(inner_p):
                assert faults.active() is inner_p
            assert faults.active() is outer_p
        assert faults.active() is None
        with pytest.raises(TypeError):
            faults.arm("not a plan")


class TestInjectors:
    def test_poison_tree_nans_inexact_leaves_only(self):
        tree = {"w": jnp.ones(3), "n": jnp.arange(4)}
        out = faults.poison_tree(tree)
        assert bool(jnp.isnan(out["w"]).all())
        assert bool((out["n"] == tree["n"]).all())   # ints untouched

    def test_bitflip_flips_exactly_one_element_and_roundtrips(self):
        tree = {"a": jnp.ones((4, 4)), "b": jnp.zeros(7)}
        once = faults.bitflip_tree(tree, leaf=0, index=5, bit=30)
        diff = np.asarray(once["a"]) != np.asarray(tree["a"])
        assert diff.sum() == 1
        assert bool((np.asarray(once["b"]) == 0).all())
        twice = faults.bitflip_tree(once, leaf=0, index=5, bit=30)
        np.testing.assert_array_equal(np.asarray(twice["a"]),
                                      np.asarray(tree["a"]))

    def test_kill_lanes(self):
        mask = np.ones(8, np.float32)
        m1 = faults.kill_lanes(mask, FaultEvent(0, "dead_lane", lane=3),
                               pods=2)
        assert m1[3] == 0.0 and m1.sum() == 7.0
        m2 = faults.kill_lanes(m1, FaultEvent(0, "dead_pod", pod=1),
                               pods=2)
        assert (m2[4:] == 0.0).all() and m2.sum() == 3.0
        assert mask.sum() == 8.0             # input not mutated
        with pytest.raises(ValueError, match="lane-kill"):
            faults.kill_lanes(mask, FaultEvent(0, "timeout"), pods=2)


class TestArmedIdleParity:
    """The zero-overhead contract: unarmed fits never see the resilient
    driver, and an armed-but-idle (empty) plan produces the same
    training — bit-exact on the exact wire."""

    def test_unarmed_fit_untouched(self):
        grid, X, y, data, lf, uf, w0 = _problem()
        ms = {}
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=8, merge_every=4, merge_state=ms)
        assert "resilience_report" not in ms

    def test_armed_idle_exact_wire_bit_exact(self):
        grid, X, y, data, lf, uf, w0 = _problem()
        w_plain, h_plain = grid.fit(
            init_state=w0, local_fn=lf, update_fn=uf, data=data,
            steps=24, merge_every=4)
        with faults.armed(FaultPlan()):
            w_armed, h_armed = grid.fit(
                init_state=w0, local_fn=lf, update_fn=uf, data=data,
                steps=24, merge_every=4)
        np.testing.assert_array_equal(np.asarray(w_plain),
                                      np.asarray(w_armed))
        assert len(h_armed) == len(h_plain) == 24
        for a, b in zip(h_plain, h_armed):
            assert float(a["loss"]) == float(b["loss"])

    def test_armed_idle_compressed_wire_close(self):
        grid, X, y, data, lf, uf, w0 = _problem()
        cfg = WIRES["int8ef"]
        w_plain, _ = grid.fit(
            init_state=w0, local_fn=lf, update_fn=uf, data=data,
            steps=24, merge_every=4, merge_compression=cfg)
        with faults.armed(FaultPlan()):
            w_armed, _ = grid.fit(
                init_state=w0, local_fn=lf, update_fn=uf, data=data,
                steps=24, merge_every=4, merge_compression=cfg)
        np.testing.assert_allclose(np.asarray(w_plain),
                                   np.asarray(w_armed), atol=2e-2)

    def test_armed_controller_plan_warns_and_skips_injection(self):
        grid, X, y, data, lf, uf, w0 = _problem(n_vdpus=4)
        ms = {}
        with faults.armed(FaultPlan(events=(
                FaultEvent(0, "nan_lane", lane=0),))):
            with pytest.warns(MergeFallbackWarning,
                              match="controller-driven"):
                w, _ = grid.fit(
                    init_state=w0, local_fn=lf, update_fn=uf,
                    data=data, steps=8, merge_plan="auto",
                    merge_state=ms)
        assert bool(jnp.isfinite(jnp.asarray(w)).all())


class TestSurvivorMerges:
    def test_dead_lane_still_converges(self, tmp_path):
        grid, X, y, data, lf, uf, w0 = _problem()
        fp = FaultPlan(events=(FaultEvent(1, "dead_lane", lane=2),))
        w, hist, rep = drive_fit(
            grid, init_state=w0, local_fn=lf, update_fn=uf, data=data,
            steps=48, plan=MergePlan(cadence=4), fault_plan=fp,
            recovery=RecoveryPolicy(backoff_base_s=0.0),
            ckpt=str(tmp_path))
        assert rep["survivors"] == 7
        assert len(hist) == 48
        # the survivors converge to the least squares of *their* rows —
        # near, not equal to, the full-data closed form
        assert _err(w, X, y) < 5e-2

    def test_dead_lanes_are_monotone_across_rollback(self, tmp_path):
        """A lane killed before a divergence stays dead after the
        rollback, whatever the restored snapshot says."""
        grid, X, y, data, lf, uf, w0 = _problem()
        fp = FaultPlan(events=(
            FaultEvent(1, "dead_lane", lane=0),
            FaultEvent(3, "nan_lane", lane=5),
        ))
        w, _, rep = drive_fit(
            grid, init_state=w0, local_fn=lf, update_fn=uf, data=data,
            steps=32, plan=MergePlan(cadence=4), fault_plan=fp,
            recovery=RecoveryPolicy(backoff_base_s=0.0),
            ckpt=str(tmp_path))
        assert rep["restarts"] >= 1
        assert rep["survivors"] == 7
        assert bool(jnp.isfinite(jnp.asarray(w)).all())

    def test_metrics_are_survivor_weighted(self, tmp_path):
        """History after a lane death stays finite: the masked mean
        excludes the dead lane instead of averaging in garbage."""
        grid, X, y, data, lf, uf, w0 = _problem()
        fp = FaultPlan(events=(FaultEvent(0, "dead_pod", pod=1),),
                       pods=4)
        w, hist, rep = drive_fit(
            grid, init_state=w0, local_fn=lf, update_fn=uf, data=data,
            steps=24, plan=MergePlan(cadence=4), fault_plan=fp,
            recovery=RecoveryPolicy(backoff_base_s=0.0),
            ckpt=str(tmp_path))
        assert rep["survivors"] == 6     # 8 lanes, pod = 8//4 = 2 wide
        assert all(np.isfinite(float(m["loss"])) for m in hist)

    @multidevice
    def test_mesh_survivor_matrix(self, tmp_path):
        """The shard_map path: every wire survives a mixed fault plan
        on a real (pod, data) mesh."""
        from repro.core.pim import make_mesh_grid
        X, y, _ = datasets.regression(KEY, 256, 8)
        grid = make_mesh_grid(16, pods=2)
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.1)
        ws = closed_form(X, y)
        fp = FaultPlan(events=(
            FaultEvent(2, "nan_lane", lane=3),
            FaultEvent(4, "dead_pod", pod=1),
            FaultEvent(6, "wire_bitflip", leaf=0, index=1, bit=29),
        ))
        pol = RecoveryPolicy(max_restarts=10, degrade_after=2,
                             spike_factor=50.0, backoff_base_s=0.0)
        for name, cfg in WIRES.items():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                w, hist, rep = drive_fit(
                    grid, init_state=w0, local_fn=lf, update_fn=uf,
                    data=data, steps=48, plan=MergePlan(
                        cadence=4, compression=cfg),
                    fault_plan=fp, recovery=pol,
                    ckpt=str(tmp_path / name), ckpt_every_rounds=2)
            assert bool(jnp.isfinite(jnp.asarray(w)).all()), name
            assert len(hist) == 48, name
            assert rep["survivors"] == 8, name
            assert float(jnp.linalg.norm(jnp.asarray(w) - ws)) < 1.0, \
                name


class TestFaultMatrix:
    """Every fault class x every wire format: training finishes all
    steps, the final state is finite, accuracy stays within a bounded
    factor of the same wire's unfaulted baseline, and the recovery
    trace replays to the reported final plan."""

    _baseline: dict = {}

    @classmethod
    def _baseline_err(cls, wire, tmp_path):
        if wire not in cls._baseline:
            grid, X, y, data, lf, uf, w0 = _problem()
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                w, _, _ = drive_fit(
                    grid, init_state=w0, local_fn=lf, update_fn=uf,
                    data=data, steps=64,
                    plan=MergePlan(cadence=4,
                                   compression=WIRES[wire]))
            cls._baseline[wire] = _err(w, X, y)
        return cls._baseline[wire]

    def _plan_for(self, kind):
        if kind == "nan_lane":
            return FaultPlan(events=(
                FaultEvent(3, "nan_lane", lane=2),))
        if kind == "wire_bitflip":
            return FaultPlan(events=(
                FaultEvent(3, "wire_bitflip", leaf=0, index=2,
                           bit=30),))
        if kind == "dead_lane":
            return FaultPlan(events=(
                FaultEvent(2, "dead_lane", lane=5),))
        if kind == "dead_pod":
            return FaultPlan(events=(
                FaultEvent(2, "dead_pod", pod=1),), pods=4)
        if kind == "timeout":
            return FaultPlan(events=(
                FaultEvent(3, "timeout", duration_s=0.002),))
        # torn_ckpt alone never fails a run — tear EVERY save and pair
        # with a later divergence, so the rollback must detect the torn
        # bytes, quarantine them and fall back to the origin state
        return FaultPlan(events=tuple(
            FaultEvent(i, "torn_ckpt") for i in range(64)
        ) + (FaultEvent(9, "nan_lane", lane=1),))

    @pytest.mark.parametrize("wire", sorted(WIRES))
    @pytest.mark.parametrize(
        "kind", ["nan_lane", "wire_bitflip", "dead_lane", "dead_pod",
                 "timeout", "torn_ckpt"])
    def test_matrix(self, wire, kind, tmp_path):
        grid, X, y, data, lf, uf, w0 = _problem()
        plan = MergePlan(cadence=4, compression=WIRES[wire])
        fp = self._plan_for(kind)
        pol = RecoveryPolicy(max_restarts=10, degrade_after=2,
                             spike_factor=50.0, backoff_base_s=0.0)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            w, hist, rep = drive_fit(
                grid, init_state=w0, local_fn=lf, update_fn=uf,
                data=data, steps=64, plan=plan, fault_plan=fp,
                recovery=pol, ckpt=str(tmp_path),
                ckpt_every_rounds=2)
        # finite final state, full history
        assert bool(jnp.isfinite(jnp.asarray(w)).all())
        assert len(hist) == 64
        assert all(np.isfinite(float(m["loss"])) for m in hist)
        # oracle-bounded accuracy: within a constant factor of the same
        # wire's own unfaulted error (lossy wires have intrinsic error
        # a fault must not be graded against)
        base = self._baseline_err(wire, tmp_path)
        assert _err(w, X, y) <= 2.0 * base + 0.25, \
            f"{wire}/{kind}: err {_err(w, X, y)} vs baseline {base}"
        # replayable recovery trace: folding the recorded degrade
        # events over the start plan lands on the reported final plan
        states = replay_trace(rep["trace"], start_plan=plan)
        final = states[-1] if states else plan.describe()
        assert final == rep["final_plan"]
        rollbacks = [e for e in rep["trace"]
                     if e["action"] == "rollback"]
        assert len(rollbacks) == rep["restarts"]
        if kind == "timeout":
            assert rep["restarts"] >= 1
            assert all(e["transient"] for e in rollbacks)
            # transient faults never climb the ladder
            assert rep["final_plan"] == plan.describe()
        if kind == "nan_lane":
            assert rep["restarts"] >= 1
        # wire_bitflip gets no restart floor: a flip that SHRINKS a
        # weight (bit already set) is sub-threshold corruption the
        # driver absorbs — the accuracy bound above is its contract
        if kind == "torn_ckpt":
            assert rep["restarts"] >= 1
            corrupt = [d for d in os.listdir(tmp_path)
                       if ".corrupt" in d]
            assert corrupt, "torn checkpoint was never quarantined"

    def test_recovery_none_propagates_the_failure(self):
        grid, X, y, data, lf, uf, w0 = _problem()
        fp = FaultPlan(events=(FaultEvent(1, "nan_lane", lane=0),))
        with pytest.raises(FloatingPointError, match="non-finite"):
            drive_fit(grid, init_state=w0, local_fn=lf, update_fn=uf,
                      data=data, steps=16, plan=MergePlan(cadence=4),
                      fault_plan=fp)

    def test_timeout_raises_dispatch_timeout_without_recovery(self):
        grid, X, y, data, lf, uf, w0 = _problem()
        fp = FaultPlan(events=(
            FaultEvent(1, "timeout", duration_s=0.001),))
        with pytest.raises(DispatchTimeout):
            drive_fit(grid, init_state=w0, local_fn=lf, update_fn=uf,
                      data=data, steps=16, plan=MergePlan(cadence=4),
                      fault_plan=fp)

    def test_exhausted_restart_budget_reraises(self, tmp_path):
        grid, X, y, data, lf, uf, w0 = _problem()
        # one nan per round: even max degradation cannot outrun it
        fp = FaultPlan(events=tuple(
            FaultEvent(r, "nan_lane", lane=0) for r in range(64)))
        with pytest.raises(FloatingPointError):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                drive_fit(grid, init_state=w0, local_fn=lf,
                          update_fn=uf, data=data, steps=64,
                          plan=MergePlan(cadence=4), fault_plan=fp,
                          recovery=RecoveryPolicy(
                              max_restarts=3, backoff_base_s=0.0),
                          ckpt=str(tmp_path))


class TestRecoveryPolicy:
    def test_backoff_is_exponential_and_capped(self):
        pol = RecoveryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                             backoff_max_s=0.5)
        assert pol.backoff_s(0) == 0.0
        assert pol.backoff_s(1) == pytest.approx(0.1)
        assert pol.backoff_s(2) == pytest.approx(0.2)
        assert pol.backoff_s(10) == 0.5

    def test_degradation_ladder_order(self):
        pol = RecoveryPolicy(min_cadence=1)
        plan = MergePlan(cadence=4, overlap=True,
                         compression=WIRES["int8ef"])
        p1 = pol.degrade(plan)
        assert p1.compression is None and p1.cadence == 4
        p2 = pol.degrade(p1)
        assert p2.cadence == 2
        p3 = pol.degrade(p2)
        assert p3.cadence == 1
        p4 = pol.degrade(p3)
        assert p4.overlap is False
        assert pol.degrade(p4) is None     # exhausted

    def test_ladder_uses_the_controller_shrink_rule(self):
        from repro.tuning.controller import shrink_k
        pol = RecoveryPolicy(min_cadence=2)
        plan = MergePlan(cadence=8)
        assert pol.degrade(plan).cadence == shrink_k(8, 2)

    def test_detector_spike_and_reset(self):
        det = DivergenceDetector(factor=10.0, window=4)
        for x in (1.0, 1.1, 0.9):
            assert not det.observe(x)
        assert det.observe(50.0)           # > 10x median
        assert not det.observe(1.0)        # spike was not absorbed
        assert det.observe(float("nan"))
        det.reset()
        assert not det.observe(1e9)        # fresh window: no median yet

    def test_validation(self):
        with pytest.raises(ValueError):
            RecoveryPolicy(max_restarts=-1)
        with pytest.raises(ValueError):
            RecoveryPolicy(backoff_factor=0.5)
        with pytest.raises(ValueError):
            RecoveryPolicy(degrade_after=0)


class TestCheckpointHardening:
    def _save_one(self, tmp_path, step=0, async_save=False):
        m = CheckpointManager(str(tmp_path), async_save=async_save)
        state = {"w": jnp.arange(6.0), "n": jnp.asarray(3)}
        m.save(step, state, extra={"tag": step})
        m.wait()
        return m, state

    def test_atomic_publish_leaves_no_tmp(self, tmp_path):
        m, _ = self._save_one(tmp_path)
        assert not [d for d in os.listdir(tmp_path)
                    if d.endswith(".tmp")]

    def test_checksum_catches_corruption(self, tmp_path):
        m, state = self._save_one(tmp_path)
        assert m.validate(0)
        arrays = os.path.join(m._step_path(0), "arrays.npz")
        with open(arrays, "r+b") as f:
            f.seek(os.path.getsize(arrays) // 2)
            f.write(b"\xde\xad\xbe\xef")
        assert not m.validate(0)
        with pytest.raises(CheckpointCorruptError):
            m.restore(0, state)

    def test_structure_mismatch_stays_value_error(self, tmp_path):
        m, state = self._save_one(tmp_path)
        with pytest.raises(ValueError, match="structure"):
            m.restore(0, {"different": jnp.zeros(2)})

    def test_restore_latest_quarantines_and_falls_back(self, tmp_path):
        m, state = self._save_one(tmp_path, step=0)
        m.save(1, state, extra={"tag": 1})
        m.wait()
        arrays = os.path.join(m._step_path(1), "arrays.npz")
        with open(arrays, "r+b") as f:
            f.truncate(os.path.getsize(arrays) // 2)   # torn write
        with pytest.warns(RuntimeWarning, match="quarantined"):
            step, restored, extra = m.restore_latest(state)
        assert step == 0 and extra["tag"] == 0
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert m.steps() == [0]            # corrupt step out of sight
        assert [d for d in os.listdir(tmp_path) if ".corrupt" in d]

    def test_legacy_checkpoint_without_checksums_validates(
            self, tmp_path):
        import json
        m, state = self._save_one(tmp_path)
        mpath = os.path.join(m._step_path(0), "manifest.json")
        with open(mpath) as f:
            meta = json.load(f)
        del meta["checksums"]
        with open(mpath, "w") as f:
            json.dump(meta, f)
        assert m.validate(0)               # readability-only fallback
        _, extra = m.restore(0, state)
        assert extra["tag"] == 0

    def test_background_write_failure_surfaces_at_wait(
            self, tmp_path, monkeypatch):
        """Satellite: a failed async write re-raises at the first
        wait()/save() boundary and never advances latest_step()."""
        m, state = self._save_one(tmp_path, step=0, async_save=True)
        assert m.latest_step() == 0

        def boom(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(np, "savez", boom)
        m.save(1, state)                   # returns; failure is parked
        with pytest.raises(OSError, match="disk full"):
            m.wait()
        assert m.latest_step() == 0        # never published
        monkeypatch.undo()
        monkeypatch.setattr(np, "savez", boom)
        m.save(2, state)
        with pytest.raises(OSError, match="disk full"):
            m.save(3, state)               # surfaces at save() too
        assert m.latest_step() == 0

    def test_torn_write_injection_keys_on_save_ordinal(self, tmp_path):
        fp = FaultPlan(events=(FaultEvent(1, "torn_ckpt"),))
        with faults.armed(fp):
            m = CheckpointManager(str(tmp_path), async_save=False)
            state = {"w": jnp.arange(8.0)}
            m.save(0, state)               # ordinal 0: intact
            m.save(1, state)               # ordinal 1: torn
        assert m.validate(0)
        assert not m.validate(1)


class TestTrainerRecovery:
    """cfg.recovery on the fault-tolerant Trainer: backoff + rollback,
    loss-spike detection at flush boundaries, and the cadence
    degradation ladder for round-granular programs."""

    def _program_trainer(self, tmp_path, recovery, merge_every=4):
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        program = LinReg(lr=0.05).bind(grid, X, y)
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                            log_every=4, merge_every=merge_every,
                            recovery=recovery)
        return program, Trainer.for_program(program, cfg)

    def test_clean_run_has_empty_trace_and_fit_parity(self, tmp_path):
        pol = RecoveryPolicy(backoff_base_s=0.0, spike_factor=100.0)
        program, tr = self._program_trainer(tmp_path, pol)
        out = tr.run(16)
        assert out["recovery_trace"] == [] and out["restarts"] == 0
        res = program.fit(steps=16, merge_every=4)
        np.testing.assert_allclose(np.asarray(tr.state),
                                   np.asarray(res.state), rtol=1e-6)

    def test_spike_triggers_backoff_and_rollback(self, tmp_path):
        losses = iter([1.0, 1.0, 1.0, 1.0, 1e8] + [1.0] * 100)

        def step_fn(state, batch):
            return state + 1.0, {"loss": jnp.asarray(next(losses))}

        pol = RecoveryPolicy(max_restarts=4, backoff_base_s=0.0,
                             spike_factor=10.0)
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            log_every=2, recovery=pol)
        tr = Trainer(step_fn, jnp.zeros(2), lambda s: None, cfg)
        out = tr.run(12)
        assert out["restarts"] == 1
        ev = out["recovery_trace"][0]
        assert ev["action"] == "rollback"
        assert "loss spike" in ev["detail"]
        assert ev["to_step"] < ev["step"]
        assert [e["step"] for e in out["history"]] == list(range(12))

    def test_degradation_halves_program_cadence(self, tmp_path):
        pol = RecoveryPolicy(max_restarts=6, backoff_base_s=0.0,
                             degrade_after=2, min_cadence=1)
        program, tr = self._program_trainer(tmp_path, pol)
        orig, n_fail = tr.step_fn, {"left": 2}

        def sabotaged(state, batch):
            out = orig(state, batch)
            if n_fail["left"] > 0:
                n_fail["left"] -= 1
                raise FloatingPointError("synthetic divergence")
            return out

        tr.step_fn = sabotaged
        out = tr.run(16)
        actions = [e["action"] for e in out["recovery_trace"]]
        assert actions.count("rollback") == 2
        assert "degrade" in actions
        deg = next(e for e in out["recovery_trace"]
                   if e["action"] == "degrade")
        assert (deg["from_cadence"], deg["to_cadence"]) == (4, 2)
        assert tr._steps_per_call == 2 and tr._merge_every == 2
        # the degraded round_fn still yields one entry per local step
        assert [e["step"] for e in out["history"]] == list(range(16))
        assert bool(np.isfinite(np.asarray(tr.state[0])).all())

    def test_recovery_trace_mirrored_into_merge_state(self, tmp_path):
        ms = {}

        def step_fn(state, batch):
            return state + 1.0, {"loss": jnp.where(
                state[0] == 5.0, jnp.nan, 1.0)}

        pol = RecoveryPolicy(max_restarts=4, backoff_base_s=0.0)
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            log_every=2, recovery=pol)
        tr = Trainer(step_fn, jnp.zeros(1), lambda s: None, cfg,
                     merge_state=ms)
        with pytest.raises(FloatingPointError):
            tr.run(20)        # deterministic NaN replays to give-up
        assert ms["tuning_trace"]["recovery"] is tr.recovery_trace
        assert tr.recovery_trace     # rollbacks were recorded

    def test_recovery_budget_replaces_max_restarts(self, tmp_path):
        def step_fn(state, batch):
            return state + 1.0, {"loss": jnp.asarray(float("nan"))}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            log_every=2, max_restarts=0,
                            recovery=RecoveryPolicy(
                                max_restarts=2, backoff_base_s=0.0))
        tr = Trainer(step_fn, jnp.zeros(1), lambda s: None, cfg)
        with pytest.raises(FloatingPointError):
            tr.run(8)
        assert tr._restarts == 3       # 2 recoveries + the give-up
