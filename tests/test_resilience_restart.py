"""Restart-replay fidelity: SIGKILL a ``Trainer.for_program`` fit
mid-cadence (in a subprocess), resume from its crash-consistent
checkpoints, and require the resumed run to match an uninterrupted one
bit-for-bit — model state, the on-device sampler counter, the
error-feedback and SlowMo buffers riding the merge-state holder, the
tuning trace, and every replayed history entry."""

import os
import signal
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import LinReg
from repro.core.mlalgos.linreg import make_linreg_step
from repro.distributed.compression import CompressionConfig
from repro.distributed.merge_plan import MergePlan, SlowMo
from repro.runtime import Trainer, TrainerConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
KEY = jax.random.PRNGKey(0)

STEPS = 24          # cadence 4 -> ckpts at steps 7, 11, 15, 19 (+ final)
KILL_DISPATCH = 3   # die inside the round covering steps 8-11


def _setup():
    """Deterministic problem + a merge-state holder seeded by a prior
    compressed/SlowMo ``PimGrid.fit`` segment (``for_program`` refuses
    such plans, so the buffers ride the trainer as checkpoint cargo)."""
    X, y, _ = datasets.regression(KEY, 256, 6)
    grid = make_cpu_grid(4)
    data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.1)
    ms = {}
    grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
             steps=8, merge_state=ms,
             merge_plan=MergePlan(
                 cadence=2,
                 compression=CompressionConfig(bits=8,
                                               error_feedback=True),
                 outer=SlowMo()))
    ms["tuning_trace"] = {"note": ["segment-done"]}
    program = LinReg(lr=0.05).bind(grid, X, y)
    return program, ms


def _config(ckpt_dir):
    return TrainerConfig(ckpt_dir=str(ckpt_dir), ckpt_every=4,
                         log_every=4, merge_every=4, batch_size=8)


# The crash victim: identical setup, but the sabotaged step_fn SIGKILLs
# the process inside dispatch KILL_DISPATCH — after the round computed,
# before the trainer records or checkpoints it (a mid-cadence crash).
_CHILD = """
import os, signal, sys
sys.path.insert(0, {src!r})
import jax
sys.path.insert(0, {here!r})
from test_resilience_restart import (_setup, _config, KILL_DISPATCH,
                                     STEPS)
from repro.runtime import Trainer

program, ms = _setup()
tr = Trainer.for_program(program, _config(sys.argv[1]), merge_state=ms)
orig = tr.step_fn
calls = {{"n": 0}}

def sabotaged(state, batch):
    out = orig(state, batch)
    calls["n"] += 1
    if calls["n"] == KILL_DISPATCH:
        jax.block_until_ready(out[0])
        # the step-7 save is async: drain the writer queue first so
        # the crash tests resume fidelity, not save-queue timing
        tr.ckpt.wait()
        os.kill(os.getpid(), signal.SIGKILL)
    return out

tr.step_fn = sabotaged
tr.run(STEPS)
print("UNREACHABLE")
"""


def test_sigkill_resume_matches_uninterrupted_bit_for_bit(tmp_path):
    ckpt_dir = tmp_path / "ckpt"
    child = tmp_path / "crash_child.py"
    child.write_text(_CHILD.format(
        src=os.path.abspath(SRC),
        here=os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, str(child), str(ckpt_dir)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, proc.stderr
    assert "UNREACHABLE" not in proc.stdout
    # the crash left only merge-boundary checkpoints, newest at step 7
    steps_on_disk = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and ".corrupt" not in d
        and not d.endswith(".tmp"))
    assert steps_on_disk and steps_on_disk[-1] == 7

    # oracle: the same run, uninterrupted, in this process
    program, ms_oracle = _setup()
    tr_oracle = Trainer.for_program(
        program, _config(tmp_path / "oracle"), merge_state=ms_oracle)
    out_oracle = tr_oracle.run(STEPS)

    # resume from the victim's checkpoints
    program2, ms_resumed = _setup()
    tr2 = Trainer.for_program(program2, _config(ckpt_dir),
                              merge_state=ms_resumed)
    assert tr2.start_step == 8          # replay re-enters mid-run
    out2 = tr2.run(STEPS - tr2.start_step)

    # model state and sampler counter: bit-for-bit
    np.testing.assert_array_equal(np.asarray(tr2.state[0]),
                                  np.asarray(tr_oracle.state[0]))
    assert float(tr2.state[1]) == float(tr_oracle.state[1]) \
        == float(STEPS)
    # EF residual and SlowMo momentum round-tripped the crash
    for key in ("error", "momentum"):
        a = jax.tree.leaves(ms_resumed[key])
        b = jax.tree.leaves(ms_oracle[key])
        assert len(a) == len(b)
        for la, lb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(la),
                                          np.asarray(lb))
    # tuning trace restored from the crashed run's manifest
    assert ms_resumed["tuning_trace"]["note"] == ["segment-done"]
    # replayed history is the uninterrupted history's tail, bit-equal
    tail = out_oracle["history"][tr2.start_step:]
    assert [e["step"] for e in out2["history"]] \
        == [e["step"] for e in tail]
    for a, b in zip(out2["history"], tail):
        assert a["loss"] == b["loss"]
