"""Out-of-core streaming ingestion: the exactness and lifecycle
contracts of StreamingDataset / PartitionRotation / run_streaming_fit.

The two headline claims (data/pipeline DESIGN):

* a streaming fit whose partitions tile the dataset is bit-for-bit the
  fully-resident fit — ``shuffle=False`` single-partition == full-batch,
  and a multi-window rotation == the resident minibatch sampler with
  ``batch_size = part`` and the same seed;
* the compiled scan stays the execution engine: streaming scan ==
  streaming python oracle at every cadence, no retrace across windows.
"""

import numpy as np
import pytest

import jax

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import api
from repro.core.mlalgos.dtree import DecisionTree
from repro.core.mlalgos.kmeans import KMeans
from repro.core.mlalgos.linreg import LinReg
from repro.core.mlalgos.svm import LinearSVM
from repro.data import StreamingDataset
from repro.distributed.compression import CompressionConfig
from repro.runtime import Trainer, TrainerConfig


def _data(n=400, d=6, seed=5):
    X, y, _ = datasets.regression(jax.random.PRNGKey(seed), n, d)
    return X, y, np.asarray(X), np.asarray(y)


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(np.array_equal(np.asarray(x), np.asarray(y)))
        for x, y in zip(la, lb))


def _histories_equal(ha, hb):
    return len(ha) == len(hb) and all(
        set(ea) == set(eb) and all(np.array_equal(
            np.asarray(ea[k]), np.asarray(eb[k])) for k in ea)
        for ea, eb in zip(ha, hb))


class TestBitExactness:
    def test_exact_full_matches_resident_full_batch(self):
        """shuffle=False, partition == dataset: the rotation runs the
        IDENTICAL compiled graph — state AND history bit-equal."""
        X, y, Xn, yn = _data()
        grid = make_cpu_grid(8)
        wl = LinReg(lr=0.05)
        sd = StreamingDataset(Xn, yn, partition_rows=Xn.shape[0],
                              steps_per_window=5, shuffle=False)
        rs = api.fit(wl, grid, sd, steps=15)
        rr = api.fit(wl, grid, X, y, steps=15)
        assert _trees_equal(rs.state, rr.state)
        assert _histories_equal(rs.history, rr.history)

    @pytest.mark.parametrize("precision", ["fp32", "int8"])
    def test_rotation_matches_resident_minibatch(self, precision):
        """Multi-window rotation == resident minibatch with
        batch_size=part and the same seed — the sampler's schedule,
        lifted to the host, including the quantized paths (fixed
        global scales)."""
        X, y, Xn, yn = _data()
        grid = make_cpu_grid(8)
        wl = LinReg(lr=0.05, precision=precision)
        sd = StreamingDataset(Xn, yn, partition_rows=96,
                              steps_per_window=1, seed=3)
        part = wl.bind_stream(grid, sd).data.part
        rs = api.fit(wl, grid, sd, steps=20)
        rr = api.fit(wl, grid, X, y, steps=20, batch_size=part,
                     sample_seed=3)
        assert _trees_equal(rs.state, rr.state)
        assert _histories_equal(rs.history, rr.history)

    def test_rotation_matches_resident_minibatch_svm(self):
        X, y, Xn, yn = _data()
        yb = (yn > 0).astype(np.float32)
        grid = make_cpu_grid(8)
        wl = LinearSVM(lr=0.05)
        sd = StreamingDataset(Xn, yb, partition_rows=96,
                              steps_per_window=1, seed=9)
        part = wl.bind_stream(grid, sd).data.part
        rs = api.fit(wl, grid, sd, steps=16)
        rr = api.fit(wl, grid, X, np.asarray(yb), steps=16,
                     batch_size=part, sample_seed=9)
        assert _trees_equal(rs.state, rr.state)
        assert _histories_equal(rs.history, rr.history)

    def test_rotation_matches_resident_minibatch_kmeans(self):
        X, _, Xn, _ = _data()
        grid = make_cpu_grid(8)
        wl = KMeans(k=4, seed=2)
        sd = StreamingDataset(Xn, partition_rows=96,
                              steps_per_window=1, seed=4)
        part = wl.bind_stream(grid, sd).data.part
        rs = api.fit(wl, grid, sd, steps=10)
        rr = api.fit(wl, grid, X, steps=10, batch_size=part,
                     sample_seed=4)
        assert _trees_equal(rs.state, rr.state)

    @pytest.mark.parametrize("merge_every", [1, 2])
    def test_scan_matches_python_oracle(self, merge_every):
        """The parity oracle survives rotation at every cadence (at
        cadence k a window spans whole merge rounds)."""
        _, _, Xn, yn = _data()
        grid = make_cpu_grid(4)
        wl = LinReg(lr=0.05)
        sd = StreamingDataset(Xn, yn, partition_rows=120,
                              steps_per_window=2 * merge_every, seed=1)
        rs = api.fit(wl, grid, sd, steps=12, merge_every=merge_every)
        rp = api.fit(wl, grid, sd, steps=12, merge_every=merge_every,
                     engine="python")
        assert _trees_equal(rs.state, rp.state)
        assert _histories_equal(rs.history, rp.history)

    def test_compressed_merge_state_continues_across_windows(self):
        """EF residuals ride ``merge_state`` across window swaps exactly
        as they ride across fits — compressed streaming == compressed
        resident minibatch, bit for bit."""
        X, y, Xn, yn = _data()
        grid = make_cpu_grid(4)
        wl = LinReg(lr=0.05)
        comp = CompressionConfig(bits=8)
        sd = StreamingDataset(Xn, yn, partition_rows=120,
                              steps_per_window=1, seed=6)
        part = wl.bind_stream(grid, sd).data.part
        ms_s: dict = {}
        ms_r: dict = {}
        rs = api.fit(wl, grid, sd, steps=12,
                     merge_compression=comp, merge_state=ms_s)
        rr = api.fit(wl, grid, X, y, steps=12,
                     merge_compression=comp, merge_state=ms_r,
                     batch_size=part, sample_seed=6)
        assert _trees_equal(rs.state, rr.state)
        assert _trees_equal(ms_s.get("error"), ms_r.get("error"))

    def test_no_retrace_across_windows(self):
        """Every window hits the same compiled runner: the grid's fit
        cache must not grow with the window count."""
        _, _, Xn, yn = _data()
        grid = make_cpu_grid(4)
        wl = LinReg(lr=0.05)

        def run(steps):
            sd = StreamingDataset(Xn, yn, partition_rows=120,
                                  steps_per_window=2, seed=0)
            api.fit(wl, grid, sd, steps=steps)

        run(4)
        before = len(grid._fit_cache)
        run(16)
        assert len(grid._fit_cache) == before


class TestStreamingLifecycle:
    def test_labels_ride_inside_the_stream(self):
        _, _, Xn, yn = _data()
        sd = StreamingDataset(Xn, yn, partition_rows=64)
        with pytest.raises(ValueError, match="y=None"):
            api.fit(LinReg(), make_cpu_grid(4), sd, yn, steps=2)

    def test_cadence_alignment_enforced(self):
        _, _, Xn, yn = _data()
        sd = StreamingDataset(Xn, yn, partition_rows=64,
                              steps_per_window=3)
        with pytest.raises(ValueError, match="cadence"):
            api.fit(LinReg(), make_cpu_grid(4), sd, steps=6,
                    merge_every=2)

    def test_controller_plans_refused(self):
        _, _, Xn, yn = _data()
        sd = StreamingDataset(Xn, yn, partition_rows=64)
        with pytest.raises(ValueError, match="auto"):
            api.fit(LinReg(), make_cpu_grid(4), sd, steps=4,
                    merge_plan="auto")

    def test_non_streaming_workload_refused(self):
        _, _, Xn, yn = _data()
        sd = StreamingDataset(Xn, (yn > 0).astype(np.int32),
                              partition_rows=64)
        with pytest.raises(ValueError, match="does not support"):
            DecisionTree().bind_stream(make_cpu_grid(4), sd)

    def test_overlap_stats_recorded(self):
        _, _, Xn, yn = _data()
        grid = make_cpu_grid(4)
        sd = StreamingDataset(Xn, yn, partition_rows=120,
                              steps_per_window=2, prefetch_depth=2)
        ms: dict = {}
        api.fit(LinReg(lr=0.05), grid, sd, steps=12, merge_state=ms)
        stats = ms["streaming_trace"]
        assert stats["windows"] == 6
        assert stats["prefetch_depth"] == 2
        assert 0.0 <= stats["ingest_overlap_fraction"] <= 1.0
        assert stats["ingest_s"] > 0.0


class TestTrainerStreaming:
    def _program(self, part_rows=96, seed=3):
        _, _, Xn, yn = _data()
        sd = StreamingDataset(Xn, yn, partition_rows=part_rows,
                              steps_per_window=2, seed=seed)
        return LinReg(lr=0.05).bind_stream(make_cpu_grid(4), sd)

    def test_resume_bit_exact(self, tmp_path):
        """SIGKILL-resume replay: interrupt at a checkpoint boundary,
        rebuild everything from disk, and land bit-equal with the
        uninterrupted run (the checkpoint carries the rotation cursor
        and the windows re-gather deterministically)."""
        cfg = lambda d: TrainerConfig(ckpt_dir=str(tmp_path / d),
                                      ckpt_every=4, log_every=100)
        full = Trainer.for_program(self._program(), cfg("a"))
        full.run(12)
        Trainer.for_program(self._program(), cfg("b")).run(8)
        resumed = Trainer.for_program(self._program(), cfg("b"))
        assert resumed.start_step == 8     # restored from the ckpt
        resumed.run(4)                     # 8 done + 4 = the full 12
        assert _trees_equal(full.state, resumed.state)

    def test_stream_tag_mismatch_refused(self, tmp_path):
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            log_every=100)
        Trainer.for_program(self._program(part_rows=96), cfg).run(4)
        with pytest.raises(ValueError, match="stream"):
            Trainer.for_program(self._program(part_rows=200), cfg)

    def test_trainer_matches_api_fit(self, tmp_path):
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                            log_every=100)
        tr = Trainer.for_program(self._program(), cfg)
        tr.run(8)
        ref = self._program().fit(steps=8)
        assert _trees_equal(tr.state, ref.state)


MULTI = len(jax.devices()) >= 8
multidevice = pytest.mark.skipif(
    not MULTI,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@multidevice
class TestMeshStreaming:
    """Out-of-core rotation under the shard_map mesh engine (the
    tier-1-multidevice job): streaming windows place onto the mesh's
    data sharding and the per-window fits cross real device boundaries,
    so the bit-exactness and no-retrace contracts must hold there too —
    not just on the emulated vmap grid."""

    def _mesh_grid(self):
        from repro.core.pim import make_mesh_grid
        return make_mesh_grid(16, pods=2)

    @pytest.mark.parametrize("precision", ["fp32", "int8"])
    def test_mesh_rotation_matches_mesh_resident(self, precision):
        """Same grid on both sides: a mesh streaming fit is bit-for-bit
        the mesh resident minibatch fit (the sampler's schedule lifted
        to the host survives the shard_map path, quantized staging
        included)."""
        X, y, Xn, yn = _data()
        grid = self._mesh_grid()
        wl = LinReg(lr=0.05, precision=precision)
        sd = StreamingDataset(Xn, yn, partition_rows=96,
                              steps_per_window=1, seed=3)
        part = wl.bind_stream(grid, sd).data.part
        rs = api.fit(wl, grid, sd, steps=12)
        rr = api.fit(wl, grid, X, y, steps=12, batch_size=part,
                     sample_seed=3)
        assert _trees_equal(rs.state, rr.state)
        assert _histories_equal(rs.history, rr.history)

    def test_mesh_streaming_tracks_vmap_streaming(self):
        """Mesh vs emulated grid on the same stream: exact wires differ
        only by psum association order, so the streamed trajectories
        track within float tolerance."""
        _, _, Xn, yn = _data()
        wl = LinReg(lr=0.05)

        def run(grid):
            sd = StreamingDataset(Xn, yn, partition_rows=96,
                                  steps_per_window=1, seed=3)
            return api.fit(wl, grid, sd, steps=12)

        rm = run(self._mesh_grid())
        rv = run(make_cpu_grid(16))
        np.testing.assert_allclose(np.asarray(rm.state),
                                   np.asarray(rv.state), atol=1e-6)

    def test_mesh_no_retrace_across_windows(self):
        """Window swaps reuse the compiled shard_map runner — the mesh
        grid's fit cache must not grow with the window count."""
        _, _, Xn, yn = _data()
        grid = self._mesh_grid()
        wl = LinReg(lr=0.05)

        def run(steps):
            sd = StreamingDataset(Xn, yn, partition_rows=120,
                                  steps_per_window=2, seed=0)
            api.fit(wl, grid, sd, steps=steps)

        run(4)
        before = len(grid._fit_cache)
        run(16)
        assert len(grid._fit_cache) == before
