"""The serving layer: Workload.predict, the bucketed PredictRunner,
the micro-batching queue, and the checkpoint-backed ModelRegistry."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import make_cpu_grid
from repro.core.mlalgos import api
from repro.core.mlalgos.dtree import DecisionTree
from repro.core.mlalgos.kmeans import KMeans, kmeans_assign_points
from repro.core.mlalgos.linreg import LinReg, linreg_predict
from repro.core.mlalgos.logreg import LogReg, logreg_predict
from repro.core.mlalgos.multinomial import (MultinomialLogReg,
                                            multinomial_predict)
from repro.core.mlalgos.svm import LinearSVM, svm_predict
from repro.serving import (Backpressure, MicroBatchQueue, ModelRegistry,
                           PredictRunner)

N, D = 96, 5


def _problem(name, seed=0):
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (N, D))
    if name == "multinomial":
        y = jax.random.randint(jax.random.PRNGKey(seed + 1), (N,), 0, 3)
    elif name in ("logreg", "svm"):
        y = (jax.random.normal(jax.random.PRNGKey(seed + 1), (N,))
             > 0).astype(jnp.float32)
    elif name == "kmeans":
        y = None
    else:
        y = jax.random.normal(jax.random.PRNGKey(seed + 1), (N,))
    return X, y


def _workload(name, precision="fp32"):
    return {
        "linreg": lambda: LinReg(lr=0.05, precision=precision),
        "logreg": lambda: LogReg(lr=0.2, precision=precision),
        "svm": lambda: LinearSVM(lr=0.05, precision=precision),
        "multinomial": lambda: MultinomialLogReg(
            n_classes=3, lr=0.2, precision=precision),
        "kmeans": lambda: KMeans(k=4, precision=precision),
    }[name]()


def _trained(name, precision="fp32", steps=8):
    wl = _workload(name, precision)
    X, y = _problem(name)
    grid = make_cpu_grid(4)
    return wl, np.asarray(X, np.float32), \
        api.fit(wl, grid, X, y, steps=steps).state


FWD = {"linreg": linreg_predict, "logreg": logreg_predict,
       "svm": svm_predict, "multinomial": multinomial_predict,
       "kmeans": kmeans_assign_points}


class TestPredictProtocol:
    """Workload.predict: bit-exact with the eval forward at fp32, and
    pad-invariant at every precision (the bucketing contract)."""

    @pytest.mark.parametrize("name", sorted(FWD))
    def test_fp32_matches_eval_forward(self, name):
        wl, X, state = _trained(name)
        np.testing.assert_array_equal(
            np.asarray(wl.predict(state, X)),
            np.asarray(FWD[name](state, X)))

    @pytest.mark.parametrize("name", sorted(FWD))
    @pytest.mark.parametrize("precision", ["fp32", "int8"])
    def test_pad_invariance(self, name, precision):
        """Appended zero rows never change the first n outputs — zero
        rows cannot move a per-feature quantization absmax, and every
        forward reduction is row-local."""
        wl, X, state = _trained(name, precision)
        Xp = np.zeros((X.shape[0] + 13, D), np.float32)
        Xp[: X.shape[0]] = X
        np.testing.assert_array_equal(
            np.asarray(wl.predict(state, X)),
            np.asarray(wl.predict(state, Xp))[: X.shape[0]])

    def test_dtree_predicts_on_host(self):
        X, _ = _problem("linreg")
        y = (np.asarray(X[:, 0]) > 0).astype(np.int32)
        wl = DecisionTree(max_depth=2, n_classes=2)
        res = wl.run(make_cpu_grid(4), X, y)
        pred = wl.predict(res.state, X)
        assert pred.shape == (N,)
        assert set(np.asarray(pred).tolist()) <= {0, 1}

    def test_dtree_refused_by_compiled_runner(self):
        """predict_device=False: the host-loop forward cannot be traced
        — the runner must refuse it loudly, not crash in jit."""
        wl = DecisionTree(max_depth=2, n_classes=2)
        with pytest.raises(ValueError, match="predict_device"):
            PredictRunner(wl, state=None)


class TestPredictRunner:
    def test_bucketed_predict_matches_direct(self):
        """Every request size — sub-bucket, exact bucket, oversize
        split — returns exactly workload.predict on the unpadded
        rows."""
        wl, X, state = _trained("linreg")
        r = PredictRunner(wl, state, buckets=(8, 32))
        rng = np.random.default_rng(0)
        for n in (1, 3, 8, 9, 32, 33, 64, 77):
            Xq = rng.standard_normal((n, D)).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(r.predict(Xq)),
                np.asarray(wl.predict(state, Xq)), rtol=1e-6)

    def test_int8_bucketed_predict_matches_direct(self):
        wl, X, state = _trained("svm", "int8")
        r = PredictRunner(wl, state, buckets=(8, 32))
        np.testing.assert_allclose(
            np.asarray(r.predict(X[:11])),
            np.asarray(wl.predict(state, X[:11])), rtol=1e-6)

    def test_zero_steady_compile_misses(self):
        """The acceptance gate: after warmup, mixed request sizes —
        including oversize splits — never compile again."""
        wl, X, state = _trained("linreg")
        r = PredictRunner(wl, state, buckets=(8, 32))
        r.warmup(D)
        assert r.compile_misses == 2
        rng = np.random.default_rng(1)
        for n in (1, 5, 8, 17, 32, 40, 100):
            r.predict(rng.standard_normal((n, D)).astype(np.float32))
        assert r.steady_compile_misses == 0
        assert r.bucket_hits > 0

    def test_bucket_ladder_selection(self):
        wl, _, state = _trained("linreg")
        r = PredictRunner(wl, state, buckets=(8, 32, 128))
        assert r.bucket_for(1) == 8
        assert r.bucket_for(8) == 8
        assert r.bucket_for(9) == 32
        assert r.bucket_for(128) == 128
        assert r.bucket_for(129) is None

    def test_equal_configs_share_grid_cached_executables(self):
        """Two runners for equal estimator configs on one grid share
        compiled buckets — the second runner's warmup is all cache
        hits (fn_signature keys the workload by value)."""
        grid = make_cpu_grid(4)
        wl, X, state = _trained("linreg")
        a = PredictRunner(LinReg(lr=0.05), state, grid=grid)
        a.warmup(D)
        assert a.compile_misses == len(a.buckets)
        b = PredictRunner(LinReg(lr=0.05), state + 1.0, grid=grid)
        b.warmup(D)
        assert b.compile_misses == 0

    def test_state_is_argument_not_constant(self):
        """A hot-swapped state must change predictions through the SAME
        executable — the state is never baked in as a constant."""
        wl, X, state = _trained("linreg")
        r = PredictRunner(wl, state, buckets=(8,))
        r.warmup(D)
        before = np.asarray(r.predict(X[:4]))
        r.state = jax.tree.map(lambda l: l * 2.0, state)
        after = np.asarray(r.predict(X[:4]))
        assert r.steady_compile_misses == 0
        assert not np.array_equal(before, after)

    def test_run_stream_matches_predict_in_order(self):
        wl, X, state = _trained("logreg")
        r = PredictRunner(wl, state, buckets=(8, 32))
        rng = np.random.default_rng(2)
        feed = [rng.standard_normal((n, D)).astype(np.float32)
                for n in (8, 3, 32, 17, 8)]
        outs = list(r.run_stream(feed))
        assert len(outs) == len(feed)
        for Xb, out in zip(feed, outs):
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(r.predict(Xb)),
                                       rtol=1e-6)

    def test_run_stream_rejects_oversize(self):
        wl, _, state = _trained("linreg")
        r = PredictRunner(wl, state, buckets=(8,))
        with pytest.raises(ValueError, match="ladder"):
            list(r.run_stream([np.zeros((9, D), np.float32)]))

    def test_input_validation(self):
        wl, _, state = _trained("linreg")
        r = PredictRunner(wl, state)
        with pytest.raises(ValueError, match="rows, features"):
            r.predict(np.zeros((D,), np.float32))
        with pytest.raises(ValueError, match="empty"):
            r.predict(np.zeros((0, D), np.float32))
        with pytest.raises(ValueError, match="positive"):
            PredictRunner(wl, state, buckets=(0, 8))


class _StubRunner:
    """Scriptable predict target for queue tests (no jax dispatch)."""

    def __init__(self, delay_s=0.0, gate=None, fail=False):
        self.delay_s = delay_s
        self.gate = gate
        self.fail = fail
        self.batch_sizes = []

    def predict(self, X):
        if self.gate is not None:
            self.gate.wait()
        if self.fail:
            raise RuntimeError("model exploded")
        if self.delay_s:
            time.sleep(self.delay_s)
        self.batch_sizes.append(X.shape[0])
        return np.asarray(X).sum(axis=1)


class TestMicroBatchQueue:
    def test_results_match_and_latency_recorded(self):
        q = MicroBatchQueue(_StubRunner(), max_batch=8, max_wait_ms=1.0)
        rows = np.arange(20, dtype=np.float32).reshape(5, 4)
        tickets = [q.submit(r) for r in rows]
        got = np.asarray([t.get(timeout=10.0) for t in tickets])
        np.testing.assert_allclose(got, rows.sum(axis=1))
        q.close()
        s = q.stats()
        assert s["requests"] == 5
        assert all(t.latency_s >= 0 for t in tickets)
        assert "p50_ms" in s and "p99_ms" in s

    def test_backlog_coalesces_into_batches(self):
        """With the worker busy on a slow batch, followers pile up and
        must be served together — the coalescing win under load."""
        stub = _StubRunner(delay_s=0.02)
        q = MicroBatchQueue(stub, max_batch=64, max_wait_ms=1.0)
        tickets = [q.submit(np.zeros(3, np.float32), block=True)
                   for _ in range(40)]
        for t in tickets:
            t.get(timeout=10.0)
        q.close()
        assert q.batches_served < 40
        assert max(stub.batch_sizes) > 1

    def test_max_batch_bounds_coalescing(self):
        gate = threading.Event()
        q = MicroBatchQueue(_StubRunner(gate=gate), max_batch=4,
                            max_wait_ms=1.0)
        tickets = [q.submit(np.zeros(2, np.float32), block=True)
                   for _ in range(9)]
        gate.set()
        for t in tickets:
            t.get(timeout=10.0)
        q.close()
        assert q.batches_served >= 3          # 9 rows / max_batch 4

    def test_backpressure_surfaces_at_the_edge(self):
        gate = threading.Event()
        q = MicroBatchQueue(_StubRunner(gate=gate), max_batch=1,
                            max_wait_ms=0.5, max_pending=2)
        held = [q.submit(np.zeros(2, np.float32), block=True)]
        time.sleep(0.05)                      # worker blocks on gate
        for _ in range(2):
            held.append(q.submit(np.zeros(2, np.float32)))
        with pytest.raises(Backpressure):
            while True:                       # queue drain is racy by a
                held.append(                  # slot; the cap is 2 + the
                    q.submit(np.zeros(2, np.float32)))  # in-flight head
        gate.set()
        for t in held:
            t.get(timeout=10.0)
        q.close()

    def test_errors_propagate_to_every_ticket(self):
        q = MicroBatchQueue(_StubRunner(fail=True), max_batch=4,
                            max_wait_ms=1.0)
        t = q.submit(np.zeros(2, np.float32), block=True)
        with pytest.raises(RuntimeError, match="exploded"):
            t.get(timeout=10.0)
        q.close()

    def test_close_drains_submitted_requests(self):
        gate = threading.Event()
        q = MicroBatchQueue(_StubRunner(gate=gate), max_batch=4,
                            max_wait_ms=0.5)
        tickets = [q.submit(np.zeros(2, np.float32), block=True)
                   for _ in range(7)]
        gate.set()
        q.close()
        for t in tickets:
            assert t.get(timeout=0.1) == 0.0
        with pytest.raises(RuntimeError, match="closed"):
            q.submit(np.zeros(2, np.float32))

    def test_compiled_end_to_end_with_registry_version(self):
        """Full path: registry source, compiled runner — results match
        the direct forward and tickets carry the serving version."""
        wl, X, state = _trained("linreg")
        reg = ModelRegistry(wl, state)
        reg.publish(state, version=3)
        q = MicroBatchQueue(reg, max_batch=8, max_wait_ms=1.0)
        tickets = [q.submit(X[i]) for i in range(6)]
        got = np.asarray([t.get(timeout=30.0) for t in tickets])
        q.close()
        np.testing.assert_allclose(
            got, np.asarray(linreg_predict(state, X[:6])), rtol=1e-6)
        assert all(t.version == 3 for t in tickets)


class TestModelRegistry:
    def _save(self, tmp_path, step, state, *, wrap=False):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"model": state, "merge_error": jnp.zeros(2)} if wrap \
            else state
        mgr.save(step, tree, extra={"step": step})
        return mgr

    def test_publish_and_current_snapshot(self):
        wl, X, state = _trained("linreg")
        reg = ModelRegistry(wl, state)
        with pytest.raises(RuntimeError, match="no published"):
            reg.current()
        reg.publish(state, version=0)
        version, runner = reg.current()
        assert version == 0 and reg.version == 0
        np.testing.assert_allclose(
            np.asarray(runner.predict(X[:4])),
            np.asarray(linreg_predict(state, X[:4])), rtol=1e-6)

    def test_hot_swap_reuses_compiled_buckets(self):
        """Same-shaped new version on a shared grid: zero recompiles
        (the state is an argument of the cached executables)."""
        grid = make_cpu_grid(4)
        wl, X, state = _trained("linreg")
        reg = ModelRegistry(wl, state, grid=grid)
        r0 = reg.publish(state, version=0)
        r0.warmup(D)
        old = reg.current()
        r1 = reg.publish(jax.tree.map(lambda l: l * 2, state), version=1)
        r1.warmup(D)
        assert r1.compile_misses == 0
        assert reg.version == 1
        # the superseded snapshot keeps serving (no in-flight drop)
        np.testing.assert_allclose(
            np.asarray(old[1].predict(X[:4])),
            np.asarray(linreg_predict(state, X[:4])), rtol=1e-6)

    def test_load_v1_bare_layout(self, tmp_path):
        wl, X, state = _trained("linreg")
        self._save(tmp_path, 5, state)
        reg = ModelRegistry(wl, jnp.zeros_like(state),
                            ckpt_dir=str(tmp_path))
        assert reg.refresh() == 5
        _, runner = reg.current()
        np.testing.assert_array_equal(np.asarray(runner.state),
                                      np.asarray(state))

    def test_load_v2_model_subtree_layout(self, tmp_path):
        """The Trainer's {"model": ..., "merge_*": ...} wrapping: the
        model subtree is selected by manifest name."""
        wl, X, state = _trained("linreg")
        self._save(tmp_path, 9, state, wrap=True)
        reg = ModelRegistry(wl, jnp.zeros_like(state),
                            ckpt_dir=str(tmp_path))
        assert reg.refresh() == 9
        _, runner = reg.current()
        np.testing.assert_array_equal(np.asarray(runner.state),
                                      np.asarray(state))

    def test_refresh_skips_corrupt_newest(self, tmp_path):
        """sha256 validation: a tampered newest checkpoint is skipped
        and the newest VALID step is published instead — the restore
        path's trust model, inherited."""
        wl, X, state = _trained("linreg")
        mgr = self._save(tmp_path, 2, state)
        mgr.save(4, state + 1.0, extra={"step": 4})
        with open(os.path.join(mgr._step_path(4), "arrays.npz"),
                  "r+b") as f:
            f.seek(30)
            f.write(b"\xff\xff\xff\xff")
        reg = ModelRegistry(wl, jnp.zeros_like(state),
                            ckpt_dir=str(tmp_path))
        assert reg.refresh() == 2
        np.testing.assert_array_equal(
            np.asarray(reg.current()[1].state), np.asarray(state))

    def test_refresh_without_newer_keeps_current(self, tmp_path):
        wl, X, state = _trained("linreg")
        self._save(tmp_path, 3, state)
        reg = ModelRegistry(wl, jnp.zeros_like(state),
                            ckpt_dir=str(tmp_path))
        assert reg.refresh() == 3
        assert reg.refresh() == 3             # nothing newer: unchanged
        assert reg.version == 3

    def test_mismatched_layout_refused(self, tmp_path):
        wl, X, state = _trained("linreg")
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"something_else": jnp.zeros(3)})
        reg = ModelRegistry(wl, jnp.zeros_like(state),
                            ckpt_dir=str(tmp_path))
        with pytest.raises(ValueError, match="neither"):
            reg.load_step(1)

    def test_no_ckpt_dir_refuses_refresh(self):
        wl, _, state = _trained("linreg")
        reg = ModelRegistry(wl, state)
        with pytest.raises(RuntimeError, match="ckpt_dir"):
            reg.refresh()
