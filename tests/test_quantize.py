"""Unit + property tests for fixed-point arithmetic (paper insight I1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as qz


class TestQFormat:
    def test_roundtrip_error_bound(self):
        fmt = qz.Q3_12
        x = jnp.linspace(-7.9, 7.9, 1001)
        err = jnp.abs(fmt.dequantize(fmt.quantize(x)) - x)
        assert float(jnp.max(err)) <= 0.5 / fmt.scale + 1e-7

    def test_saturation(self):
        fmt = qz.Q1_6  # int8, range ~[-2, 2)
        q = fmt.quantize(jnp.asarray([100.0, -100.0]))
        assert int(q[0]) == 127 and int(q[1]) == -128

    def test_mul_matches_float(self):
        fmt = qz.Q3_12
        a, b = jnp.asarray(1.5), jnp.asarray(-2.25)
        prod = fmt.dequantize(fmt.mul(fmt.quantize(a), fmt.quantize(b)))
        assert abs(float(prod) - (-3.375)) < 2.0 / fmt.scale

    def test_stochastic_rounding_unbiased(self):
        fmt = qz.QFormat(int_bits=3, frac_bits=4)
        x = jnp.full((20000,), 0.53)            # between grid points
        q = fmt.quantize(x, stochastic=True, key=jax.random.PRNGKey(0))
        mean = float(jnp.mean(fmt.dequantize(q)))
        assert abs(mean - 0.53) < 5e-3


class TestSymmetric:
    def test_scale_shape_per_feature(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 7))
        q = qz.quantize_symmetric(x, bits=8, axis=0)
        assert q.scale.shape == (1, 7)
        err = jnp.abs(q.dequantize() - x)
        assert float(jnp.max(err / q.scale)) <= 0.5 + 1e-5

    @given(bits=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
    @settings(max_examples=15, deadline=None)
    def test_error_bounded_by_half_step(self, bits, seed):
        x = np.random.default_rng(seed).normal(size=(33,)).astype(
            np.float32)
        q = qz.quantize_symmetric(jnp.asarray(x), bits=bits)
        err = np.abs(np.asarray(q.dequantize()) - x)
        assert err.max() <= float(q.scale) * 0.5 + 1e-6


class TestHybridDot:
    def test_exact_vs_int64(self):
        rng = np.random.default_rng(0)
        a = rng.integers(-32768, 32767, (37, 130), dtype=np.int16)
        b = rng.integers(-32768, 32767, (130, 5), dtype=np.int16)
        want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.float64)
        got = np.asarray(qz.hybrid_dot(jnp.asarray(a), jnp.asarray(b)),
                         np.float64)
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1.0)
        assert rel.max() < 1e-6          # f32 combine rounding only

    def test_int8_path(self):
        rng = np.random.default_rng(1)
        a = rng.integers(-128, 127, (16, 300), dtype=np.int8)
        b = rng.integers(-128, 127, (300, 8), dtype=np.int8)
        want = a.astype(np.int64) @ b.astype(np.int64)
        got = np.asarray(qz.hybrid_dot(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_chunking_safe_for_large_k(self):
        # K large enough that a naive int32 dot of int16 operands would
        # overflow — the limb decomposition must stay exact
        rng = np.random.default_rng(2)
        a = rng.integers(-32768, 32767, (4, 9000), dtype=np.int16)
        b = rng.integers(-32768, 32767, (9000, 3), dtype=np.int16)
        want = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.float64)
        got = np.asarray(qz.hybrid_dot(jnp.asarray(a), jnp.asarray(b)),
                         np.float64)
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1.0)
        assert rel.max() < 1e-5


class TestErrorFeedback:
    def test_accumulated_error_stays_bounded(self):
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (256,))
        err = jnp.zeros_like(g)
        for i in range(20):
            q, err = qz.ef_quantize(g, err, bits=4)
        # EF residual must not blow up (stays within one quant step)
        assert float(jnp.max(jnp.abs(err))) <= float(q.scale) * 0.51

    def test_ef_mean_recovered(self):
        # with error feedback, the time-average of dequantized grads
        # converges to the true gradient
        g = jnp.full((64,), 0.0173)
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        n = 50
        for _ in range(n):
            q, err = qz.ef_quantize(g, err, bits=4)
            acc = acc + q.dequantize()
        np.testing.assert_allclose(np.asarray(acc / n), 0.0173, atol=2e-4)
