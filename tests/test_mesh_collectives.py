"""Bit-parity regression tests for the mesh collectives.

The mesh collectives in ``distributed.collectives`` and the
``mesh=None`` emulation in ``distributed.compression`` /
``core.quantize`` are two implementations of ONE wire definition.  The
contract pinned here: with a single participant on the slow axis the
collective is **bit-identical** to the emulation, for every dtype the
grids train in and every legal bit width.  (Before the mesh engine
landed, the collectives quantized in native leaf precision while the
emulation quantized in float32 — a latent divergence for bf16/f16
leaves that no test executed; these are its regression tests.)

Participants are emulated with ``jax.vmap(axis_name=...)`` — SPMD
semantics with no device requirement, so these run in the plain
single-device tier-1 job too.  ``tests/test_mesh_engine.py`` covers
the same collectives under a real 8-device mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.distributed import collectives as coll
from repro.distributed import compression as comp
from repro.distributed.compression import CompressionConfig

KEY = jax.random.PRNGKey(7)
DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)
BITS = (2, 4, 8, 16)


def _sample(dtype, shape=(37,), scale=3.0, key=KEY):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def _hop1(fn, *args):
    """Run a collective with ONE participant on axis "hop" (vmap SPMD
    emulation: psum/pmax over a size-1 named axis are identities)."""
    stacked = jax.tree.map(lambda x: x[None], args)
    out = jax.vmap(fn, axis_name="hop")(*stacked)
    return jax.tree.map(lambda x: x[0], out)


def _bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))


class TestHop1BitParity:
    @pytest.mark.parametrize("dtype", DTYPES, ids=str)
    @pytest.mark.parametrize("bits", BITS)
    def test_quantized_psum_matches_quantize_symmetric(self, dtype,
                                                       bits):
        x = _sample(dtype)
        got = _hop1(lambda v: coll.quantized_psum(v, "hop", bits=bits),
                    x)
        want = qz.quantize_symmetric(x, bits=bits).dequantize(x.dtype)
        _bits_equal(got, want)

    @pytest.mark.parametrize("dtype", DTYPES, ids=str)
    @pytest.mark.parametrize("bits", BITS)
    def test_quantized_psum_ef_matches_ef_quantize(self, dtype, bits):
        x = _sample(dtype)
        e = _sample(dtype, scale=0.1, key=jax.random.PRNGKey(8))
        got, got_err = _hop1(
            lambda v, r: coll.quantized_psum_ef(v, r, "hop",
                                                bits=bits), x, e)
        q, want_err = qz.ef_quantize(x, e, bits=bits)
        _bits_equal(got, q.dequantize(x.dtype))
        _bits_equal(got_err, want_err)

    @pytest.mark.parametrize("dtype", DTYPES, ids=str)
    def test_quantized_psum_ef_with_f32_error_buffer(self, dtype):
        """The ``init_error_state`` layout: float32 residuals for ANY
        leaf dtype.  The residual must subtract the wire cast to the
        *input's* dtype (``ef_quantize``'s definition), not the
        promoted target's."""
        x = _sample(dtype)
        e = _sample(jnp.float32, scale=0.1, key=jax.random.PRNGKey(9))
        got, got_err = _hop1(
            lambda v, r: coll.quantized_psum_ef(v, r, "hop", bits=8),
            x, e)
        q, want_err = qz.ef_quantize(x, e, bits=8)
        _bits_equal(got, q.dequantize(x.dtype))
        _bits_equal(got_err, want_err)

    @pytest.mark.parametrize("dtype", DTYPES, ids=str)
    @pytest.mark.parametrize("bits", (None, 2, 8))
    def test_sparse_psum_ef_matches_topk_emulation(self, dtype, bits):
        """The sparse collective against the exact per-leaf math of
        ``ef_compress_tree``'s top-k branch (``topk_keep`` shared,
        quantization math in f32)."""
        x = _sample(dtype)
        e = _sample(dtype, scale=0.1, key=jax.random.PRNGKey(10))
        got, got_err = _hop1(
            lambda v, r: coll.sparse_psum_ef(v, r, "hop", frac=0.25,
                                             bits=bits), x, e)
        cfg = CompressionConfig(bits=bits, top_k_frac=0.25)
        out_tree, err_tree = comp.ef_compress_tree(
            {"g": x}, {"g": e}, cfg)
        _bits_equal(got, out_tree["g"])
        _bits_equal(got_err, err_tree["g"])


class TestCompressedReduceTreeParity:
    """The full tree-level reduce (what ``merge_pending`` runs inside
    shard_map) against the full ``mesh=None`` emulation — mixed trees,
    integer passthrough included."""

    CFGS = {
        "int8_ef": CompressionConfig(bits=8),
        "int8_no_ef": CompressionConfig(bits=8, error_feedback=False),
        "int2": CompressionConfig(bits=2),
        "int16": CompressionConfig(bits=16),
        "topk_int8": CompressionConfig(bits=8, top_k_frac=0.25),
        "topk_raw": CompressionConfig(bits=None, top_k_frac=0.25),
    }

    def _tree(self):
        return {
            "w32": _sample(jnp.float32, (17,)),
            "wb16": _sample(jnp.bfloat16, (4, 5),
                            key=jax.random.PRNGKey(11)),
            "counts": jnp.asarray([3, 0, 12, 7], jnp.int32),
        }

    @pytest.mark.parametrize("name", sorted(CFGS))
    def test_matches_ef_compress_tree(self, name):
        base = dataclasses_replace_axes(self.CFGS[name])
        tree = self._tree()
        err = comp.init_error_state(tree)

        def reduce_fn(t, e):
            return comp.compressed_reduce(t, e, base)

        got, got_err = jax.vmap(jax.vmap(reduce_fn, axis_name="data"),
                                axis_name="pod")(
            jax.tree.map(lambda x: x[None, None], tree),
            jax.tree.map(lambda x: x[None, None], err))
        got = jax.tree.map(lambda x: x[0, 0], got)
        got_err = jax.tree.map(lambda x: x[0, 0], got_err)

        want, want_err = comp.ef_compress_tree(tree, err,
                                               self.CFGS[name])
        for k in tree:
            _bits_equal(got[k], want[k])
            _bits_equal(got_err[k], want_err[k])

    def test_integer_leaf_passes_through_exact(self):
        tree = self._tree()
        err = comp.init_error_state(tree)

        def reduce_fn(t, e):
            return comp.compressed_reduce(
                t, e, dataclasses_replace_axes(self.CFGS["int8_ef"]))

        # two pods: the int leaf must come back as the exact sum
        got, _ = jax.vmap(jax.vmap(reduce_fn, axis_name="data"),
                          axis_name="pod")(
            jax.tree.map(lambda x: jnp.stack([x, x])[:, None], tree),
            jax.tree.map(lambda x: jnp.stack([x, x])[:, None], err))
        np.testing.assert_array_equal(
            np.asarray(got["counts"][0, 0]),
            2 * np.asarray(tree["counts"]))


def dataclasses_replace_axes(cfg: CompressionConfig) -> CompressionConfig:
    """The configs above are wire definitions; bind them to the vmap
    axis names used by these tests."""
    import dataclasses
    return dataclasses.replace(cfg, slow_axis="pod",
                               fast_axes=("data",))


class TestMultiParticipant:
    """Shared-scale integer accumulation across participants (vmap axis
    size > 1) against a hand-rolled numpy oracle."""

    def test_quantized_psum_uses_one_shared_grid(self):
        n, bits = 4, 8
        xs = 2.5 * jax.random.normal(KEY, (n, 23))
        got = jax.vmap(
            lambda v: coll.quantized_psum(v, "hop", bits=bits),
            axis_name="hop")(xs)

        qmax = 2 ** (bits - 1) - 1
        x64 = np.asarray(xs, np.float64).astype(np.float32)
        scale = max(np.abs(x64).max(), 1e-12) / qmax
        q = np.clip(np.round(x64 / scale), -qmax - 1, qmax)
        want = (q.sum(axis=0) * scale).astype(np.float32)
        for i in range(n):
            np.testing.assert_allclose(np.asarray(got[i]), want,
                                       rtol=1e-6, atol=1e-6)

    def test_sparse_psum_sums_local_wires(self):
        n = 3
        xs = jax.random.normal(KEY, (n, 40))
        es = jnp.zeros_like(xs)
        got, _ = jax.vmap(
            lambda v, r: coll.sparse_psum_ef(v, r, "hop", frac=0.2,
                                             bits=None),
            axis_name="hop")(xs, es)
        want = np.sum([np.asarray(qz.topk_keep(xs[i], 0.2))
                       for i in range(n)], axis=0)
        np.testing.assert_allclose(np.asarray(got[0]), want,
                                   rtol=1e-6, atol=1e-6)

    def test_ef_residuals_reconstruct_the_exact_sum(self):
        """Σ(wire_i) + Σ(residual_i) == Σ(x_i + e_i): nothing is lost,
        only deferred — the invariant that makes EF training O(1) from
        exact."""
        n = 4
        xs = jax.random.normal(KEY, (n, 31))
        es = 0.1 * jax.random.normal(jax.random.PRNGKey(12), (n, 31))
        got, errs = jax.vmap(
            lambda v, r: coll.quantized_psum_ef(v, r, "hop", bits=8),
            axis_name="hop")(xs, es)
        lhs = np.asarray(got[0]) + np.asarray(errs).sum(axis=0)
        rhs = np.asarray(xs + es).sum(axis=0)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)
