"""Kernel-dispatch layer: Pallas (interpret) vs pure-jnp reference parity
for every mlalgo hot spot, plus the block-padding regression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, lut as lutm, make_cpu_grid
from repro.core import quantize as qz
from repro.core.mlalgos import (train_linreg, train_logreg, train_kmeans,
                                train_dtree)
from repro.core.mlalgos.dtree import dtree_predict
from repro.kernels import dispatch, ops, ref
from repro.kernels.fxp_matmul import fxp_matmul

KEY = jax.random.PRNGKey(0)


class TestFxpPadding:
    @pytest.mark.parametrize("M,K,N", [(300, 130, 70), (1, 1, 1),
                                       (257, 513, 129)])
    def test_non_aligned_shapes(self, M, K, N):
        """Regression: the seed hard-asserted block divisibility."""
        a = jax.random.randint(KEY, (M, K), -128, 128, jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 1), (K, N),
                               -128, 128, jnp.int8)
        out = fxp_matmul(a, b, interpret=True)
        want = ref.fxp_matmul_ref(a, b)
        assert out.shape == (M, N) and out.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_non_aligned_via_ops(self):
        a = jax.random.randint(KEY, (300, 130), -128, 128, jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 2), (130, 70),
                               -128, 128, jnp.int8)
        np.testing.assert_array_equal(np.asarray(ops.fxp_matmul(a, b)),
                                      np.asarray(ref.fxp_matmul_ref(a, b)))


class TestHybridMatmul:
    @pytest.mark.parametrize("adt,bdt", [(jnp.int8, jnp.int8),
                                         (jnp.int8, jnp.int16),
                                         (jnp.int16, jnp.int16)])
    def test_matches_hybrid_dot(self, adt, bdt):
        lim_a = 128 if adt == jnp.int8 else 32768
        lim_b = 128 if bdt == jnp.int8 else 32768
        a = jax.random.randint(KEY, (37, 19), -lim_a, lim_a).astype(adt)
        b = jax.random.randint(jax.random.fold_in(KEY, 3), (19, 5),
                               -lim_b, lim_b).astype(bdt)
        out = dispatch.hybrid_matmul(a, b)
        want = qz.hybrid_dot(a, b)
        assert out.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_k_chunking_matches_hybrid_dot(self):
        """K larger than k_chunk must chunk (overflow guard parity)."""
        a = jax.random.randint(KEY, (8, 100), -32768, 32767
                               ).astype(jnp.int16)
        b = jax.random.randint(jax.random.fold_in(KEY, 11), (100, 2),
                               -32768, 32767).astype(jnp.int16)
        out = dispatch.hybrid_matmul(a, b, k_chunk=32)
        want = qz.hybrid_dot(a, b, k_chunk=32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_vmapped_as_in_local_fn(self):
        """map_reduce vmaps local_fn over the vDPU axis — the Pallas path
        must batch."""
        a = jax.random.randint(KEY, (4, 33, 7), -128, 128, jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 4), (7, 1),
                               -32768, 32767).astype(jnp.int16)
        out = jax.vmap(lambda x: dispatch.hybrid_matmul(x, b))(a)
        want = jax.vmap(lambda x: qz.hybrid_dot(x, b))(a)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


class TestWeightedKernels:
    def test_kmeans_partials_masks_padding(self):
        x = jax.random.normal(KEY, (200, 6))
        c = jax.random.normal(jax.random.fold_in(KEY, 5), (4, 6))
        w = (jax.random.uniform(jax.random.fold_in(KEY, 6), (200,))
             > 0.3).astype(jnp.float32)
        s1, c1, e1 = dispatch.kmeans_partials(x, c, w)
        s2, c2, e2 = ref.kmeans_assign_ref(x, c, w)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-4, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_allclose(float(e1), float(e2), rtol=1e-5)

    def test_level_histogram_masks_padding(self):
        N, F, nodes, bins, classes = 333, 5, 4, 8, 3
        node = jax.random.randint(KEY, (N,), 0, nodes)
        xb = jax.random.randint(jax.random.fold_in(KEY, 7), (N, F), 0,
                                bins)
        y = jax.random.randint(jax.random.fold_in(KEY, 8), (N,), 0,
                               classes)
        w = (jax.random.uniform(jax.random.fold_in(KEY, 9), (N,))
             > 0.5).astype(jnp.float32)
        h1 = dispatch.level_histogram(node, xb, y, w, n_nodes=nodes,
                                      n_bins=bins, n_classes=classes)
        h2 = ref.split_hist_ref(node, xb, y, nodes, bins, classes, w)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
        # weighted total: each feature column sums to Σw
        np.testing.assert_allclose(np.asarray(h1).sum(axis=(0, 2, 3)),
                                   float(jnp.sum(w)) * np.ones(F))

    def test_lut_apply_matches_lookup(self):
        t = lutm.sigmoid_lut(512)
        x = jax.random.normal(KEY, (123,)) * 5         # odd 1D shape
        out = dispatch.lut_apply(t, x)
        want = lutm.lut_lookup(t, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)


class TestUseKernelsToggle:
    def test_flag_flips_and_restores(self):
        assert dispatch.kernels_enabled()
        with dispatch.use_kernels(False):
            assert not dispatch.kernels_enabled()
        assert dispatch.kernels_enabled()

    def test_reference_path_matches_kernel_path(self):
        a = jax.random.randint(KEY, (40, 12), -32768, 32767
                               ).astype(jnp.int16)
        b = jax.random.randint(jax.random.fold_in(KEY, 10), (12, 3),
                               -32768, 32767).astype(jnp.int16)
        out_k = dispatch.hybrid_matmul(a, b)
        with dispatch.use_kernels(False):
            out_r = dispatch.hybrid_matmul(a, b)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


class TestEndToEndParity:
    """Each mlalgo trained through the Pallas dispatch must match the
    pure-jnp reference path (the paper's accuracy-parity claim, now at
    the kernel boundary)."""

    def test_linreg_int8(self):
        X, y, _ = datasets.regression(KEY, 600, 8)
        grid = make_cpu_grid(8)
        r_k = train_linreg(grid, X, y, lr=0.05, steps=40,
                           precision="int8")
        with dispatch.use_kernels(False):
            r_r = train_linreg(grid, X, y, lr=0.05, steps=40,
                               precision="int8")
        np.testing.assert_allclose(np.asarray(r_k.w), np.asarray(r_r.w),
                                   atol=1e-5, rtol=1e-5)

    def test_logreg_int16_lut(self):
        X, y, _ = datasets.binary_classification(KEY, 600, 6)
        grid = make_cpu_grid(8)
        r_k = train_logreg(grid, X, y, lr=0.5, steps=40,
                           precision="int16", sigmoid="lut")
        with dispatch.use_kernels(False):
            r_r = train_logreg(grid, X, y, lr=0.5, steps=40,
                               precision="int16", sigmoid="lut")
        np.testing.assert_allclose(np.asarray(r_k.w), np.asarray(r_r.w),
                                   atol=1e-5, rtol=1e-5)

    def test_kmeans_int8(self):
        X, _, _ = datasets.blobs(KEY, 600, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r_k = train_kmeans(grid, X, 3, iters=8, precision="int8")
        with dispatch.use_kernels(False):
            r_r = train_kmeans(grid, X, 3, iters=8, precision="int8")
        np.testing.assert_allclose(np.asarray(r_k.centroids),
                                   np.asarray(r_r.centroids),
                                   atol=1e-4, rtol=1e-5)

    def test_dtree_bins(self):
        X, y = datasets.mixture_classification(KEY, 600, 6, n_classes=2)
        grid = make_cpu_grid(8)
        r_k = train_dtree(grid, X, y, max_depth=3, n_bins=16,
                          n_classes=2)
        with dispatch.use_kernels(False):
            r_r = train_dtree(grid, X, y, max_depth=3, n_bins=16,
                              n_classes=2)
        np.testing.assert_array_equal(
            np.asarray(dtree_predict(r_k.tree, X)),
            np.asarray(dtree_predict(r_r.tree, X)))
        np.testing.assert_array_equal(np.asarray(r_k.tree.feature),
                                      np.asarray(r_r.tree.feature))
