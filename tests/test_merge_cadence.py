"""Merge cadence (``fit(merge_every=k)``) semantics.

Three contracts pin the feature:
  * ``merge_every=1`` is bit-exact with the PR 1 merge-per-step engine
    for all four mlalgos (it takes the original code path),
  * ``merge_every=k>1`` matches a hand-rolled local-SGD oracle
    (k scaled local steps per vDPU, then state averaging) and converges
    within tolerance of cadence 1 on linreg/logreg,
  * sweeping ``merge_every`` re-uses the scan engine's compile cache
    (one runner per cadence, no growth on repeat sweeps).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import (train_linreg, train_logreg, train_kmeans,
                                train_dtree)
from repro.core.mlalgos.linreg import closed_form
from repro.core.mlalgos.logreg import accuracy
from repro.runtime import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


class TestCadenceOneBitExact:
    """merge_every=1 must equal the PR 1 engine (python-loop oracle)."""

    def test_linreg(self):
        X, y, _ = datasets.regression(KEY, 400, 8)
        grid = make_cpu_grid(8)
        r_cad = train_linreg(grid, X, y, lr=0.05, steps=40, merge_every=1)
        r_pr1 = train_linreg(grid, X, y, lr=0.05, steps=40,
                             engine="python")
        np.testing.assert_array_equal(np.asarray(r_cad.w),
                                      np.asarray(r_pr1.w))
        np.testing.assert_array_equal(
            np.asarray(r_cad.history[-1]["loss"]),
            np.asarray(r_pr1.history[-1]["loss"]))

    def test_logreg(self):
        X, y, _ = datasets.binary_classification(KEY, 400, 6)
        grid = make_cpu_grid(8)
        r_cad = train_logreg(grid, X, y, lr=0.5, steps=30, merge_every=1)
        r_pr1 = train_logreg(grid, X, y, lr=0.5, steps=30,
                             engine="python")
        np.testing.assert_array_equal(np.asarray(r_cad.w),
                                      np.asarray(r_pr1.w))

    def test_kmeans(self):
        X, _, _ = datasets.blobs(KEY, 500, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r_cad = train_kmeans(grid, X, 3, iters=8, merge_every=1)
        r_pr1 = train_kmeans(grid, X, 3, iters=8, engine="python")
        np.testing.assert_array_equal(np.asarray(r_cad.centroids),
                                      np.asarray(r_pr1.centroids))

    def test_dtree_cadence_is_documented_noop(self):
        """The tree always merges every level (discrete split commits
        cannot be averaged) — any cadence must give the same tree."""
        X, y = datasets.mixture_classification(KEY, 600, 6, 2)
        grid = make_cpu_grid(8)
        r1 = train_dtree(grid, X, y, max_depth=3, merge_every=1)
        r4 = train_dtree(grid, X, y, max_depth=3, merge_every=4)
        np.testing.assert_array_equal(np.asarray(r1.tree.feature),
                                      np.asarray(r4.tree.feature))
        np.testing.assert_array_equal(np.asarray(r1.tree.threshold),
                                      np.asarray(r4.tree.threshold))
        np.testing.assert_array_equal(np.asarray(r1.tree.leaf_value),
                                      np.asarray(r4.tree.leaf_value))
        assert r1.history == r4.history


class TestLocalSGDOracle:
    """Cadence k>1 must equal the hand-rolled local-SGD recurrence:
    per vDPU, k GD steps on n_vdpus-scaled shard gradients, then an
    average of the per-vDPU weights."""

    def test_linreg_k3_matches_numpy_oracle(self):
        V, per, d, lr, k = 2, 4, 2, 0.05, 3
        X = np.asarray(jax.random.normal(KEY, (V * per, d)), np.float64)
        y = X @ np.array([1.0, -1.0])
        n = V * per

        w_oracle = np.zeros((d,))
        for _ in range(2):                       # two merge rounds
            locals_ = []
            for v in range(V):
                Xv = X[v * per:(v + 1) * per]
                yv = y[v * per:(v + 1) * per]
                wv = w_oracle.copy()
                for _ in range(k):
                    g = Xv.T @ (Xv @ wv - yv) * V     # n_vdpus pre-scale
                    wv = wv - lr * g / n
                locals_.append(wv)
            w_oracle = np.mean(locals_, axis=0)       # hierarchical avg

        grid = make_cpu_grid(V)
        res = train_linreg(grid, jnp.asarray(X, jnp.float32),
                           jnp.asarray(y, jnp.float32), lr=lr,
                           steps=2 * k, merge_every=k)
        np.testing.assert_allclose(np.asarray(res.w), w_oracle,
                                   rtol=1e-5, atol=1e-6)

    def test_scan_matches_python_engine_with_remainder(self):
        """steps % k != 0: the trailing short round must match the
        per-round python dispatch loop bit-exactly."""
        X, y, _ = datasets.regression(KEY, 300, 5)
        grid = make_cpu_grid(4)
        r_scan = train_linreg(grid, X, y, lr=0.05, steps=12,
                              merge_every=5)
        r_py = train_linreg(grid, X, y, lr=0.05, steps=12,
                            merge_every=5, engine="python")
        np.testing.assert_array_equal(np.asarray(r_scan.w),
                                      np.asarray(r_py.w))
        assert len(r_scan.history) == len(r_py.history) == 12
        np.testing.assert_array_equal(
            np.asarray(r_scan.history[-1]["loss"]),
            np.asarray(r_py.history[-1]["loss"]))

    def test_remainder_of_one_step(self):
        """steps % k == 1: the trailing round is a single merge-per-step
        dispatch — it must not crash and must match the python engine
        (regression: the (1, rem, ...) metric unstacking assumed
        rem > 1)."""
        X, y, _ = datasets.regression(KEY, 200, 4)
        grid = make_cpu_grid(4)
        r_scan = train_linreg(grid, X, y, lr=0.05, steps=5, merge_every=4)
        r_py = train_linreg(grid, X, y, lr=0.05, steps=5, merge_every=4,
                            engine="python")
        assert len(r_scan.history) == len(r_py.history) == 5
        np.testing.assert_array_equal(np.asarray(r_scan.w),
                                      np.asarray(r_py.w))

    def test_callback_sees_every_local_step(self):
        X, y, _ = datasets.regression(KEY, 200, 4)
        grid = make_cpu_grid(4)
        seen = []
        data, n, = None, None
        from repro.core.mlalgos import make_linreg_step
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)
        grid.fit(init_state=w0, local_fn=lf, update_fn=uf, data=data,
                 steps=10, merge_every=4,
                 callback=lambda s, st, m: seen.append(s))
        assert seen == list(range(10))


class TestAccuracyVsCadence:
    """Amortising the merge must not cost convergence (the PIM-Opt
    claim): cadence 4 lands within tolerance of cadence 1."""

    def test_linreg_converges_within_tolerance(self):
        X, y, _ = datasets.regression(KEY, 800, 8)
        w_star = np.asarray(closed_form(X, y))
        grid = make_cpu_grid(8)
        errs = {}
        for k in (1, 4):
            res = train_linreg(grid, X, y, lr=0.05, steps=160,
                               merge_every=k)
            errs[k] = float(np.linalg.norm(np.asarray(res.w) - w_star))
        assert errs[4] <= 1.5 * errs[1] + 0.05, errs

    def test_logreg_accuracy_within_tolerance(self):
        X, y, _ = datasets.binary_classification(KEY, 800, 8)
        grid = make_cpu_grid(8)
        accs = {}
        for k in (1, 4):
            res = train_logreg(grid, X, y, lr=0.5, steps=120,
                               merge_every=k)
            accs[k] = accuracy(res.w, X, y)
        assert accs[4] >= accs[1] - 0.02, accs


class TestCompileCacheCadence:
    def test_cadence_sweep_reuses_cache(self):
        """Each cadence compiles one runner; repeating the sweep must
        not grow the cache or re-create runners."""
        grid = make_cpu_grid(4)
        X = jax.random.normal(KEY, (64, 3))
        data, n = grid.shard_rows(X)

        def local_fn(w, sl):
            return {"g": sl["X"].T @ (sl["X"] @ w * sl["w"])}

        def update_fn(w, merged):
            return w - 0.01 * merged["g"] / n, {}

        def sweep():
            out = {}
            for k in (1, 2, 4):
                grid.fit(init_state=jnp.zeros((3,)), local_fn=local_fn,
                         update_fn=update_fn, data=data, steps=8,
                         merge_every=k)
                out[k] = grid.make_runner(local_fn, update_fn,
                                          merge_every=k)
            return out

        first = sweep()
        size_after_first = len(grid._fit_cache)
        second = sweep()
        assert len(grid._fit_cache) == size_after_first
        for k in (1, 2, 4):
            assert first[k] is second[k]
        assert len({id(r) for r in first.values()}) == 3

    def test_runner_traces_bounded(self):
        """A cadence fit compiles at most chunk + remainder lengths."""
        grid = make_cpu_grid(4)
        X = jax.random.normal(KEY, (32, 2))
        data, n = grid.shard_rows(X)

        def local_fn(w, sl):
            return {"g": jnp.sum(sl["X"] * sl["w"][:, None], axis=0)}

        def update_fn(w, merged):
            return w - 0.01 * merged["g"] / n, {}

        for _ in range(3):
            grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                     update_fn=update_fn, data=data, steps=20,
                     merge_every=4, scan_chunk=3)
        runner = grid.make_runner(local_fn, update_fn, merge_every=4)
        assert runner._cache_size() <= 2


class TestValidation:
    def test_fit_rejects_nonpositive_cadence(self):
        grid = make_cpu_grid(4)
        data, n = grid.shard_rows(jnp.zeros((8, 2)))
        with pytest.raises(ValueError):
            grid.fit(init_state=jnp.zeros((2,)),
                     local_fn=lambda w, sl: {"g": jnp.zeros((2,))},
                     update_fn=lambda w, m: (w, {}),
                     data=data, steps=1, merge_every=0)

    def test_make_runner_rejects_nonpositive_cadence(self):
        grid = make_cpu_grid(4)
        with pytest.raises(ValueError):
            grid.make_runner(lambda w, sl: w, lambda w, m: (w, {}),
                             merge_every=0)

    def test_dtree_rejects_nonpositive_cadence(self):
        X, y = datasets.mixture_classification(KEY, 100, 4, 2)
        grid = make_cpu_grid(4)
        with pytest.raises(ValueError):
            train_dtree(grid, X, y, max_depth=2, merge_every=0)


class TestTrainerCadence:
    """The fault-tolerant Trainer defers metric flush / finite check to
    merge boundaries: mid-round metrics are shard-local."""

    def _mk(self, merge_every):
        def step_fn(state, batch):
            w = state["w"] - 0.1 * batch["g"]
            return {"w": w}, {"loss": jnp.sum(w ** 2)}

        cfg = TrainerConfig(log_every=1, merge_every=merge_every)
        return Trainer(step_fn, {"w": jnp.ones((2,))},
                       lambda s: {"g": jnp.ones((2,))}, cfg)

    def test_flush_only_at_merge_boundaries(self):
        tr = self._mk(merge_every=3)
        seen = []
        tr.run(7, callback=lambda step, m: seen.append(step))
        # merge boundaries: steps 2, 5 ((step+1) % 3 == 0) + final step
        assert seen == [2, 5, 6]
        # every step still lands in history, in order
        assert [e["step"] for e in tr.history] == list(range(7))

    def test_no_spurious_early_checkpoint(self, tmp_path):
        """cadence > 1 must not fire a near-initial checkpoint at the
        first merge boundary: the ckpt multiple a flush window covers
        must itself be past start_step (regression)."""
        def step_fn(state, batch):
            return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                            merge_every=8, log_every=1000)
        tr = Trainer(step_fn, {"w": jnp.ones(())}, lambda s: {}, cfg)
        tr.run(20)
        tr.ckpt.wait()
        # only the unconditional end-of-run save — no step-7 checkpoint
        assert tr.ckpt.steps() == [19]

    def test_default_cadence_keeps_pr1_behaviour(self):
        tr = self._mk(merge_every=1)
        seen = []
        tr.run(3, callback=lambda step, m: seen.append(step))
        assert seen == [0, 1, 2]
