"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core import lut as lutm

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Kh,S,D", [
        (1, 2, 2, 128, 64),       # MHA
        (2, 4, 2, 256, 64),       # GQA 2:1
        (1, 8, 1, 128, 128),      # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, H, Kh, S, D, causal):
        q = jax.random.normal(KEY, (B, H, S, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Kh, S, D))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Kh, S, D))
        out = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                  block_k=64)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_block_size_invariance(self):
        q = jax.random.normal(KEY, (1, 2, 256, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 2, 256, 64))
        v = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 2, 256, 64))
        o1 = ops.flash_attention(q, k, v, block_q=64, block_k=64)
        o2 = ops.flash_attention(q, k, v, block_q=128, block_k=256)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        q = jax.random.normal(KEY, (1, 2, 128, 64)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(KEY, 5),
                              (1, 2, 128, 64)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(KEY, 6),
                              (1, 2, 128, 64)).astype(jnp.bfloat16)
        out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=3e-2, rtol=3e-2)


class TestLutActivation:
    @pytest.mark.parametrize("entries", [256, 1024])
    @pytest.mark.parametrize("shape", [(256, 512), (128, 1024)])
    def test_matches_ref(self, entries, shape):
        t = lutm.sigmoid_lut(entries)
        x = jax.random.normal(KEY, shape, jnp.float32) * 4
        out = ops.lut_activation(x, t.table, x_min=t.x_min, x_max=t.x_max)
        want = ref.lut_activation_ref(x, t.table, t.x_min, t.x_max)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_matches_framework_lut(self):
        t = lutm.gelu_lut(512)
        x = jax.random.normal(KEY, (256, 512)) * 3
        out = ops.lut_activation(x, t.table, x_min=t.x_min, x_max=t.x_max)
        want = lutm.lut_lookup(t, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-6)


class TestFxpMatmul:
    @pytest.mark.parametrize("M,K,N", [(128, 256, 128), (256, 512, 256),
                                       (128, 1024, 128)])
    def test_exact_int32(self, M, K, N):
        a = jax.random.randint(KEY, (M, K), -128, 128, jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 7), (K, N),
                               -128, 128, jnp.int8)
        out = ops.fxp_matmul(a, b)
        want = ref.fxp_matmul_ref(a, b)
        assert out.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


class TestKMeansAssign:
    @pytest.mark.parametrize("N,D,K", [(2048, 16, 8), (1024, 32, 4)])
    def test_matches_ref(self, N, D, K):
        x = jax.random.normal(KEY, (N, D), jnp.float32)
        c = jax.random.normal(jax.random.fold_in(KEY, 8), (K, D))
        s1, c1, e1 = ops.kmeans_assign(x, c)
        s2, c2, e2 = ref.kmeans_assign_ref(x, c)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-3, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_allclose(float(e1), float(e2), rtol=1e-5)

    def test_counts_sum_to_n(self):
        x = jax.random.normal(KEY, (4096, 8))
        c = jax.random.normal(jax.random.fold_in(KEY, 9), (5, 8))
        _, counts, _ = ops.kmeans_assign(x, c)
        assert float(jnp.sum(counts)) == 4096.0

    def test_weighted_matches_ref(self):
        x = jax.random.normal(KEY, (1000, 8))
        c = jax.random.normal(jax.random.fold_in(KEY, 20), (4, 8))
        w = (jax.random.uniform(jax.random.fold_in(KEY, 21), (1000,))
             > 0.25).astype(jnp.float32)
        s1, c1, e1 = ops.kmeans_assign(x, c, w)
        s2, c2, e2 = ref.kmeans_assign_ref(x, c, w)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-3, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_allclose(float(e1), float(e2), rtol=1e-5)


class TestSplitHist:
    @pytest.mark.parametrize("N,F,nodes,bins,classes", [
        (1024, 8, 4, 16, 3), (512, 4, 2, 8, 2)])
    def test_matches_ref(self, N, F, nodes, bins, classes):
        node = jax.random.randint(KEY, (N,), 0, nodes)
        xb = jax.random.randint(jax.random.fold_in(KEY, 10), (N, F), 0,
                                bins)
        y = jax.random.randint(jax.random.fold_in(KEY, 11), (N,), 0,
                               classes)
        h1 = ops.split_hist(node, xb, y, n_nodes=nodes, n_bins=bins,
                            n_classes=classes)
        h2 = ref.split_hist_ref(node, xb, y, nodes, bins, classes)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))

    def test_total_count_conserved(self):
        N = 512
        node = jax.random.randint(KEY, (N,), 0, 4)
        xb = jax.random.randint(jax.random.fold_in(KEY, 12), (N, 4), 0, 8)
        y = jax.random.randint(jax.random.fold_in(KEY, 13), (N,), 0, 2)
        h = ops.split_hist(node, xb, y, n_nodes=4, n_bins=8, n_classes=2)
        # every feature column sees every row exactly once
        np.testing.assert_allclose(np.asarray(h).sum(axis=(0, 2, 3)),
                                   N * np.ones(4))

    def test_weighted_matches_ref(self):
        N = 300                                  # non-block-aligned too
        node = jax.random.randint(KEY, (N,), 0, 4)
        xb = jax.random.randint(jax.random.fold_in(KEY, 14), (N, 3), 0, 8)
        y = jax.random.randint(jax.random.fold_in(KEY, 15), (N,), 0, 2)
        w = (jax.random.uniform(jax.random.fold_in(KEY, 16), (N,))
             > 0.5).astype(jnp.float32)
        h1 = ops.split_hist(node, xb, y, w, n_nodes=4, n_bins=8,
                            n_classes=2)
        h2 = ref.split_hist_ref(node, xb, y, 4, 8, 2, w)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
