"""tools/bench_diff.py — the CI gate over BENCH_scaling.json artifacts."""

import importlib.util
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff", os.path.join(os.path.dirname(__file__), "..",
                               "tools", "bench_diff.py"))
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _artifact(cells=None, *, smoke=True, schema="bench_scaling/v2"):
    config = {
        "backend": "cpu", "smoke": smoke, "rows": 2048, "features": 16,
        "timed_steps": 16, "n_vdpus": [1, 4], "merge_every": [1, 4],
        "precisions": ["fp32"], "pipelines": ["baseline", "overlap"],
        "pipeline_precisions": ["fp32"],
    }
    if cells is None:
        cells = [
            {"n_vdpus": v, "precision": "fp32", "merge_every": k,
             "pipeline": p, "steps_per_s": 100.0}
            for v in (1, 4) for k in (1, 4)
            for p in ("baseline", "overlap")]
    return {"schema": schema, "config": config, "throughput": cells,
            "accuracy_vs_cadence": [], "accuracy_vs_pipeline": []}


class TestDiff:
    def test_identical_passes(self):
        art = _artifact()
        assert bench_diff.diff(art, art) == []

    def test_schema_family_mismatch_flagged(self):
        fresh = _artifact()
        committed = _artifact(schema="bench_kernels/v2")
        findings = bench_diff.diff(fresh, committed)
        assert any("schema mismatch" in f for f in findings)

    def test_schema_downgrade_flagged(self):
        """A fresh artifact must never silently drop to an OLDER schema
        than the committed reference."""
        fresh = _artifact(schema="bench_scaling/v1")
        committed = _artifact()
        findings = bench_diff.diff(fresh, committed)
        assert any("downgrade" in f for f in findings)

    def test_unparseable_schema_flagged(self):
        fresh = _artifact(schema="bench_scaling")
        findings = bench_diff.diff(fresh, _artifact())
        assert any("schema mismatch" in f for f in findings)

    def test_newer_fresh_schema_accepted(self, capsys):
        """Fresh v(N+1) over committed vN — added axes/columns — must
        pass (the upgrade path every schema bump takes through CI)."""
        fresh = _artifact(schema="bench_scaling/v3")
        committed = _artifact()
        assert bench_diff.diff(fresh, committed) == []
        assert "accepted" in capsys.readouterr().out

    def test_missing_cell_flagged(self):
        fresh = _artifact()
        dropped = fresh["throughput"][:-1]     # lose (4, fp32, 4, overlap)
        fresh = dict(fresh, throughput=dropped)
        findings = bench_diff.diff(fresh, _artifact())
        assert any("missing throughput cell" in f for f in findings)
        assert any("pipeline=overlap" in f for f in findings)

    def test_missing_section_flagged(self):
        fresh = _artifact()
        del fresh["accuracy_vs_pipeline"]
        findings = bench_diff.diff(fresh, _artifact())
        assert any("missing section" in f for f in findings)

    def test_regression_flagged_when_comparable(self):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=10.0)   # 10x slower
        findings = bench_diff.diff(fresh, _artifact())
        assert any("regression" in f for f in findings)

    def test_small_slowdown_tolerated(self):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=60.0)   # 1.7x slower
        assert bench_diff.diff(fresh, _artifact()) == []

    def test_incomparable_configs_skip_regression(self, capsys):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=1.0)
        committed = _artifact(smoke=False)     # full-size reference
        findings = bench_diff.diff(fresh, committed)
        assert findings == []
        assert "skipped" in capsys.readouterr().out

    def test_baseline_only_precisions_not_required_to_sweep_pipelines(self):
        """int16/int8 cells only exist for the baseline pipeline; the
        completeness check must honor config.pipeline_precisions."""
        art = _artifact()
        art["config"]["precisions"] = ["fp32", "int8"]
        art["throughput"] += [
            {"n_vdpus": v, "precision": "int8", "merge_every": k,
             "pipeline": "baseline", "steps_per_s": 5.0}
            for v in (1, 4) for k in (1, 4)]
        assert bench_diff.diff(art, art) == []

    def test_cli_exit_codes(self, tmp_path, capsys):
        import json
        good = tmp_path / "good.json"
        good.write_text(json.dumps(_artifact()))
        old = tmp_path / "old.json"
        old.write_text(json.dumps(_artifact(schema="bench_scaling/v1")))
        assert bench_diff.main([str(good), str(good)]) == 0
        # a fresh DOWNGRADE fails; a fresh upgrade over old passes
        assert bench_diff.main([str(old), str(good)]) == 1
        assert bench_diff.main([str(good), str(old)]) == 0


def _v3_artifact(*, drop_plan_cell=False):
    """A v3 artifact: v2 cells (implicitly plan="avg") plus the plan
    axis over plan_n_vdpus."""
    art = _artifact(schema="bench_scaling/v3")
    art["config"]["plans"] = ["slowmo", "topk"]
    art["config"]["plan_n_vdpus"] = [4]
    art["config"]["plan_precisions"] = ["fp32"]
    plan_cells = [
        {"n_vdpus": 4, "precision": "fp32", "merge_every": k,
         "pipeline": "baseline", "plan": p, "steps_per_s": 80.0}
        for k in (1, 4) for p in ("slowmo", "topk")]
    if drop_plan_cell:
        plan_cells = plan_cells[:-1]
    art["throughput"] += plan_cells
    art["accuracy_vs_plan"] = []
    return art


class TestPlanAxisVersioning:
    def test_v3_fresh_vs_v2_committed_passes(self):
        """The exact CI situation after the schema bump: the fresh
        smoke sweep carries plan columns the committed artifact
        predates — no missing-cell or schema findings."""
        assert bench_diff.diff(_v3_artifact(), _artifact()) == []

    def test_v3_plan_completeness_checked_against_own_config(self):
        """Plan cells the fresh config promises must exist — judged
        against the FRESH config, not the committed one."""
        findings = bench_diff.diff(_v3_artifact(drop_plan_cell=True),
                                   _artifact())
        assert any("missing throughput cell" in f and "plan=topk" in f
                   for f in findings)

    def test_v3_vs_v3_regression_on_plan_cells(self):
        fresh = _v3_artifact()
        committed = _v3_artifact()
        for c in fresh["throughput"]:
            if c.get("plan") == "topk":
                c["steps_per_s"] = 1.0
        findings = bench_diff.diff(fresh, committed)
        assert any("regression" in f and "plan=topk" in f
                   for f in findings)

    def test_avg_plan_cells_compare_across_versions(self):
        """v2 cells (no plan column) and v3 plan="avg" cells share a
        key, so the regression leg still covers them."""
        fresh = _v3_artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=5.0)
        findings = bench_diff.diff(fresh, _artifact())
        assert any("regression" in f and "plan=avg" in f
                   for f in findings)


def _v4_artifact(*, drop_workload_cell=False):
    """A v4 artifact: v3 cells (implicitly workload="linreg",
    batch_size="full") plus the workload x batch_size axis."""
    art = _v3_artifact()
    art["schema"] = "bench_scaling/v4"
    art["config"]["workloads"] = ["linreg", "svm", "multinomial"]
    art["config"]["workload_n_vdpus"] = [4]
    art["config"]["workload_merge_every"] = [1, 4]
    art["config"]["batch_sizes"] = ["full", 32]
    wl_cells = [
        {"workload": wl, "batch_size": bs, "n_vdpus": 4,
         "precision": "fp32", "merge_every": k, "pipeline": "baseline",
         "plan": "avg", "steps_per_s": 150.0}
        for wl in ("linreg", "svm", "multinomial")
        for bs in ("full", 32) if not (wl == "linreg" and bs == "full")
        for k in (1, 4)]
    if drop_workload_cell:
        wl_cells = wl_cells[:-1]
    art["throughput"] += wl_cells
    art["accuracy_vs_workload"] = []
    return art


class TestWorkloadAxisVersioning:
    def test_v4_fresh_vs_v3_committed_passes(self):
        """The CI situation after this schema bump: fresh smoke sweep
        carries workload/batch columns the committed v3 artifact
        predates — no missing-cell or schema findings."""
        assert bench_diff.diff(_v4_artifact(), _v3_artifact()) == []

    def test_v4_fresh_vs_v2_committed_passes(self):
        assert bench_diff.diff(_v4_artifact(), _artifact()) == []

    def test_v4_workload_completeness_checked_against_own_config(self):
        findings = bench_diff.diff(_v4_artifact(drop_workload_cell=True),
                                   _v3_artifact())
        assert any("missing throughput cell" in f
                   and "workload=multinomial" in f for f in findings)

    def test_linreg_full_batch_not_double_promised(self):
        """The (linreg, "full") point of the workload axis belongs to
        the base sweep — its absence from the workload cells is not a
        finding (the base cells already cover it)."""
        art = _v4_artifact()
        assert not any(
            c.get("workload") == "linreg" and c.get("batch_size") == "full"
            and "workloads" in str(art["config"])
            for c in art["throughput"][-10:])
        assert bench_diff.diff(art, art) == []

    def test_v4_vs_v4_regression_on_minibatch_cells(self):
        fresh = _v4_artifact()
        for c in fresh["throughput"]:
            if c.get("batch_size") == 32 and c.get("workload") == "svm":
                c["steps_per_s"] = 1.0
        findings = bench_diff.diff(fresh, _v4_artifact())
        assert any("regression" in f and "workload=svm" in f
                   and "batch_size=32" in f for f in findings)

    def test_default_keys_keep_old_cells_comparable(self):
        """A pre-v4 cell (no workload/batch columns) and a v4
        workload="linreg", batch_size="full" cell share a key."""
        pre = {"n_vdpus": 1, "precision": "fp32", "merge_every": 1,
               "pipeline": "baseline"}
        v4 = dict(pre, workload="linreg", batch_size="full", plan="avg")
        assert bench_diff._cell_key(pre) == bench_diff._cell_key(v4)


def _v5_artifact(*, drop_auto_cell=False):
    """A v5 artifact: v4 plus the ``auto`` plan in ``config.plans``
    (the self-tuning ``fit(merge_plan="auto")`` cells).  No new key
    columns — auto rides the generic plans axis."""
    art = _v4_artifact()
    art["schema"] = "bench_scaling/v5"
    art["config"]["plans"] = ["slowmo", "topk", "adaptive", "auto"]
    plan_cells = [
        {"n_vdpus": 4, "precision": "fp32", "merge_every": k,
         "pipeline": "baseline", "plan": p, "steps_per_s": 80.0}
        for k in (1, 4) for p in ("adaptive", "auto")]
    if drop_auto_cell:
        plan_cells = [c for c in plan_cells if c["plan"] != "auto"]
    art["throughput"] += plan_cells
    return art


class TestAutoPlanVersioning:
    def test_v5_fresh_vs_v4_committed_passes(self):
        """The CI situation after this schema bump: the fresh sweep's
        auto cells are extra columns over the committed v4 artifact —
        no missing-cell or schema findings."""
        assert bench_diff.diff(_v5_artifact(), _v4_artifact()) == []

    def test_v5_fresh_vs_v2_committed_passes(self):
        assert bench_diff.diff(_v5_artifact(), _artifact()) == []

    def test_v5_auto_cells_promised_by_own_config(self):
        """A sweep that silently dropped the auto cells must fail the
        completeness check — plan="auto" flows through the generic
        plans axis read from the FRESH config."""
        findings = bench_diff.diff(_v5_artifact(drop_auto_cell=True),
                                   _v4_artifact())
        assert any("missing throughput cell" in f and "plan=auto" in f
                   for f in findings)

    def test_v5_vs_v5_regression_on_auto_cells(self):
        fresh = _v5_artifact()
        for c in fresh["throughput"]:
            if c.get("plan") == "auto":
                c["steps_per_s"] = 1.0
        findings = bench_diff.diff(fresh, _v5_artifact())
        assert any("regression" in f and "plan=auto" in f
                   for f in findings)

    def test_v4_committed_never_demands_auto_cells(self):
        """Completeness is judged per-file: a committed v4 artifact
        without auto cells stays valid as the comparison base."""
        assert bench_diff.diff(_v4_artifact(), _v4_artifact()) == []


def _v6_artifact(*, mesh_grids=("2x4",), drop_mesh_cell=False,
                 drop_weak_row=False):
    """A v6 artifact: v5 plus the real-mesh cells (``mesh`` column,
    promised via ``config.mesh_grids``) and the ``weak_scaling``
    section (promised via ``config.weak_n_vdpus``)."""
    art = _v5_artifact()
    art["schema"] = "bench_scaling/v6"
    art["config"]["mesh_grids"] = list(mesh_grids)
    art["config"]["mesh_n_vdpus"] = [16]
    art["config"]["mesh_pipelines"] = ["baseline", "int8"]
    art["config"]["weak_n_vdpus"] = [64, 256]
    art["config"]["weak_rows_per_vdpu"] = 16
    mesh_cells = [
        {"n_vdpus": 16, "precision": "fp32", "merge_every": k,
         "pipeline": p, "plan": "avg", "mesh": m, "steps_per_s": 40.0}
        for m in mesh_grids for k in (1, 4)
        for p in ("baseline", "int8")]
    if drop_mesh_cell:
        mesh_cells = mesh_cells[:-1]
    art["throughput"] += mesh_cells
    weak = [{"workload": "linreg", "mesh": "none", "n_vdpus": v,
             "rows_per_vdpu": 16, "steps_per_s": 30.0}
            for v in (64, 256)]
    if drop_weak_row:
        weak = weak[:-1]
    art["weak_scaling"] = weak
    return art


class TestMeshAxisVersioning:
    def test_v6_fresh_vs_v5_committed_passes(self):
        """The CI situation after this schema bump: the fresh sweep's
        mesh cells and weak_scaling section are extra over the
        committed v5 artifact — no missing-cell or schema findings."""
        assert bench_diff.diff(_v6_artifact(), _v5_artifact()) == []

    def test_v6_fresh_vs_v2_committed_passes(self):
        assert bench_diff.diff(_v6_artifact(), _artifact()) == []

    def test_v6_mesh_completeness_checked_against_own_config(self):
        findings = bench_diff.diff(_v6_artifact(drop_mesh_cell=True),
                                   _v5_artifact())
        assert any("missing throughput cell" in f and "mesh=2x4" in f
                   for f in findings)

    def test_single_device_runtime_promises_no_mesh_cells(self):
        """config.mesh_grids is EMPTY when the generating runtime had
        one device — the promise adapts, so a devicesless CI sweep
        never flags missing mesh cells."""
        art = _v6_artifact(mesh_grids=())
        assert bench_diff.diff(art, art) == []

    def test_v6_missing_weak_row_flagged(self):
        findings = bench_diff.diff(_v6_artifact(drop_weak_row=True),
                                   _v5_artifact())
        assert any("missing weak-scaling row" in f and "256" in f
                   for f in findings)

    def test_v5_committed_never_demands_weak_rows(self):
        """A committed pre-v6 artifact promises no weak rows and stays
        valid as the comparison base."""
        assert bench_diff.diff(_v5_artifact(), _v5_artifact()) == []

    def test_v6_vs_v6_regression_on_mesh_cells(self):
        fresh = _v6_artifact()
        for c in fresh["throughput"]:
            if c.get("mesh") == "2x4":
                c["steps_per_s"] = 1.0
        findings = bench_diff.diff(fresh, _v6_artifact())
        assert any("regression" in f and "mesh=2x4" in f
                   for f in findings)

    def test_device_topology_change_skips_regression(self, capsys):
        """Forcing 8 host devices runs the emulated cells on 1/8 of
        the CPU — a topology change, not a regression.  config
        n_devices joins the comparability key."""
        fresh = _v6_artifact()
        fresh["config"]["n_devices"] = 8
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=1.0)
        committed = _v6_artifact()
        committed["config"]["n_devices"] = 1
        assert bench_diff.diff(fresh, committed) == []
        assert "skipped" in capsys.readouterr().out

    def test_default_mesh_key_keeps_old_cells_comparable(self):
        """A pre-v6 cell (no mesh column) and a v6 mesh="none" cell
        share a key, so emulated-grid cells compare across versions."""
        pre = {"n_vdpus": 1, "precision": "fp32", "merge_every": 1,
               "pipeline": "baseline"}
        v6 = dict(pre, workload="linreg", batch_size="full", plan="avg",
                  mesh="none")
        assert bench_diff._cell_key(pre) == bench_diff._cell_key(v6)


class TestMainErrorPaths:
    """The CLI must state a missing or unparseable artifact in ONE
    clear line (exit 1) — never a traceback the CI log buries."""

    def _write(self, tmp_path, name, text):
        p = tmp_path / name
        p.write_text(text)
        return str(p)

    def test_missing_baseline_is_one_clear_line(self, tmp_path, capsys):
        import json
        fresh = self._write(tmp_path, "fresh.json",
                            json.dumps(_artifact()))
        rc = bench_diff.main([fresh, str(tmp_path / "nope.json")])
        out = capsys.readouterr().out
        assert rc == 1
        assert "bench_diff: FAIL cannot read committed artifact" in out
        assert "Traceback" not in out

    def test_missing_fresh_is_one_clear_line(self, tmp_path, capsys):
        import json
        committed = self._write(tmp_path, "committed.json",
                                json.dumps(_artifact()))
        rc = bench_diff.main([str(tmp_path / "gone.json"), committed])
        out = capsys.readouterr().out
        assert rc == 1
        assert "bench_diff: FAIL cannot read fresh artifact" in out

    def test_unparseable_baseline_is_one_clear_line(self, tmp_path,
                                                    capsys):
        import json
        fresh = self._write(tmp_path, "fresh.json",
                            json.dumps(_artifact()))
        committed = self._write(tmp_path, "committed.json",
                                "{not json at all")
        rc = bench_diff.main([fresh, committed])
        out = capsys.readouterr().out
        assert rc == 1
        assert "bench_diff: FAIL committed artifact" in out
        assert "not valid JSON" in out
        assert "Traceback" not in out

    def test_valid_pair_still_passes(self, tmp_path, capsys):
        import json
        fresh = self._write(tmp_path, "fresh.json",
                            json.dumps(_artifact()))
        committed = self._write(tmp_path, "committed.json",
                                json.dumps(_artifact()))
        assert bench_diff.main([fresh, committed]) == 0
        assert "bench_diff: OK" in capsys.readouterr().out


def _stream_artifact(streaming=None, baseline=None, *, smoke=True,
                     overlap=0.95, schema="bench_streaming/v1"):
    config = {
        "backend": "cpu", "n_devices": 1, "smoke": smoke,
        "rows": 4096, "features": 32, "n_vdpus": 8,
        "steps_per_window": 4, "epochs": 1,
        "stream_workloads": ["linreg", "svm"],
        "stream_partition_rows": [512, 1024],
        "stream_depths": [0, 2],
        "overlap_floor": 0.8, "overlap_floor_depth": 2,
    }
    if streaming is None:
        streaming = [
            {"workload": wl, "partition_rows": part,
             "prefetch_depth": depth, "steps_per_s": 50.0,
             "ingest_overlap_fraction": 0.0 if depth == 0 else overlap}
            for wl in ("linreg", "svm") for part in (512, 1024)
            for depth in (0, 2)]
    if baseline is None:
        baseline = [
            {"workload": wl, "partition_rows": part,
             "steps_per_s": 40.0}
            for wl in ("linreg", "svm") for part in (512, 1024)]
    return {"schema": schema, "config": config,
            "streaming": streaming, "baseline": baseline}


class TestStreamingDiff:
    """The bench_streaming/* family: completeness from the artifact's
    own stream_* axes, the ingest-overlap floor, and regression."""

    def test_identical_passes(self):
        art = _stream_artifact()
        assert bench_diff.diff(art, art) == []

    def test_cross_family_is_schema_mismatch(self):
        findings = bench_diff.diff(_stream_artifact(), _artifact())
        assert any("schema mismatch" in f for f in findings)

    def test_missing_streaming_cell_flagged(self):
        art = _stream_artifact()
        dropped = _stream_artifact(
            streaming=[c for c in art["streaming"]
                       if not (c["workload"] == "svm"
                               and c["partition_rows"] == 1024
                               and c["prefetch_depth"] == 2)])
        findings = bench_diff.diff(dropped, art)
        assert any("missing streaming cell" in f
                   and "workload=svm" in f and "partition_rows=1024" in f
                   for f in findings)

    def test_missing_baseline_cell_flagged(self):
        art = _stream_artifact()
        dropped = _stream_artifact(
            baseline=[c for c in art["baseline"]
                      if c["partition_rows"] != 512])
        findings = bench_diff.diff(dropped, art)
        assert sum("missing baseline cell" in f for f in findings) == 2

    def test_overlap_below_floor_flagged(self):
        """depth >= overlap_floor_depth must hide >= overlap_floor of
        ingest — the PR acceptance criterion, enforced every CI run."""
        art = _stream_artifact()
        weak = _stream_artifact(overlap=0.5)
        findings = bench_diff.diff(weak, art)
        assert sum("ingest overlap below floor" in f
                   for f in findings) == 4          # every depth-2 cell

    def test_depth_zero_exempt_from_floor(self):
        """The synchronous path is the floor's control group: overlap 0
        by construction, never flagged."""
        art = _stream_artifact()
        findings = bench_diff.diff(art, art)
        assert not any("overlap" in f for f in findings)

    def test_regression_flagged_when_comparable(self):
        fresh = _stream_artifact()
        for c in fresh["streaming"]:
            c["steps_per_s"] = 10.0
        findings = bench_diff.diff(fresh, _stream_artifact())
        assert any("streaming throughput regression" in f
                   for f in findings)

    def test_regression_skipped_when_not_comparable(self, capsys):
        fresh = _stream_artifact()
        for c in fresh["streaming"]:
            c["steps_per_s"] = 10.0
        committed = _stream_artifact(smoke=False)
        findings = bench_diff.diff(fresh, committed)
        assert findings == []
        assert "not comparable" in capsys.readouterr().out

    def test_committed_repo_artifact_self_diff(self):
        """The committed BENCH_streaming.json must satisfy its own
        promises (completeness + overlap floor)."""
        import json
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_streaming.json")
        with open(path) as f:
            art = json.load(f)
        assert bench_diff.diff(art, art) == []


def _serving_artifact(serving=None, saturation=None, *, smoke=True,
                      p99=20.0, steady_misses=0,
                      schema="bench_serving/v1"):
    config = {
        "backend": "cpu", "n_devices": 1, "smoke": smoke,
        "rows": 2048, "features": 32, "n_vdpus": 8,
        "requests": 256, "max_batch": 32, "max_wait_ms": 2.0,
        "serve_workloads": ["linreg", "svm"],
        "serve_precisions": ["fp32", "int8"],
        "serve_loads": [500, 2000],
    }
    if serving is None:
        serving = [
            {"workload": wl, "precision": prec, "offered_rps": load,
             "p50_ms": 5.0, "p99_ms": p99, "throughput_rps": load * 0.95,
             "steady_compile_misses": steady_misses}
            for wl in ("linreg", "svm") for prec in ("fp32", "int8")
            for load in (500, 2000)]
    if saturation is None:
        saturation = [
            {"workload": wl, "precision": prec, "rows_per_s": 1e6,
             "steady_compile_misses": steady_misses}
            for wl in ("linreg", "svm") for prec in ("fp32", "int8")]
    return {"schema": schema, "config": config,
            "serving": serving, "saturation": saturation}


class TestServingDiff:
    """The bench_serving/* family: completeness from the artifact's own
    serve_* axes, the zero-steady-miss gate, and the inverted (p99
    latency) regression direction."""

    def test_identical_passes(self):
        art = _serving_artifact()
        assert bench_diff.diff(art, art) == []

    def test_cross_family_is_schema_mismatch(self):
        findings = bench_diff.diff(_serving_artifact(), _artifact())
        assert any("schema mismatch" in f for f in findings)

    def test_missing_serving_cell_flagged(self):
        art = _serving_artifact()
        dropped = _serving_artifact(
            serving=[c for c in art["serving"]
                     if not (c["workload"] == "svm"
                             and c["precision"] == "int8"
                             and c["offered_rps"] == 2000)])
        findings = bench_diff.diff(dropped, art)
        assert any("missing serving cell" in f and "workload=svm" in f
                   and "precision=int8" in f for f in findings)

    def test_missing_saturation_cell_flagged(self):
        art = _serving_artifact()
        dropped = _serving_artifact(
            saturation=[c for c in art["saturation"]
                        if c["precision"] != "int8"])
        findings = bench_diff.diff(dropped, art)
        assert sum("missing saturation cell" in f for f in findings) == 2

    def test_steady_compile_miss_flagged(self):
        """The warm-cache acceptance gate: ANY nonzero
        steady_compile_misses fails, comparable configs or not."""
        art = _serving_artifact()
        leaky = _serving_artifact(steady_misses=2, smoke=False)
        findings = bench_diff.diff(leaky, art)
        assert any("steady-state compile misses" in f for f in findings)

    def test_p99_regression_direction_inverted(self):
        """Latency is a lower-is-better metric: a fresh p99 ABOVE
        max_regression x committed fails; a fresh p99 far below never
        does."""
        slow = _serving_artifact(p99=100.0)
        fast = _serving_artifact(p99=20.0)
        findings = bench_diff.diff(slow, fast)
        assert any("p99 latency regression" in f for f in findings)
        assert bench_diff.diff(fast, slow) == []

    def test_saturation_regression_flagged(self):
        fresh = _serving_artifact()
        for c in fresh["saturation"]:
            c["rows_per_s"] = 1e3
        findings = bench_diff.diff(fresh, _serving_artifact())
        assert any("saturation throughput regression" in f
                   for f in findings)

    def test_regression_skipped_when_not_comparable(self, capsys):
        fresh = _serving_artifact(p99=500.0)
        committed = _serving_artifact(smoke=False)
        findings = bench_diff.diff(fresh, committed)
        assert findings == []
        assert "not comparable" in capsys.readouterr().out

    def test_committed_repo_artifact_self_diff(self):
        """The committed BENCH_serving.json must satisfy its own
        promises (completeness + zero steady misses)."""
        import json
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serving.json")
        with open(path) as f:
            art = json.load(f)
        assert bench_diff.diff(art, art) == []
