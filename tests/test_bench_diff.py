"""tools/bench_diff.py — the CI gate over BENCH_scaling.json artifacts."""

import importlib.util
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff", os.path.join(os.path.dirname(__file__), "..",
                               "tools", "bench_diff.py"))
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _artifact(cells=None, *, smoke=True, schema="bench_scaling/v2"):
    config = {
        "backend": "cpu", "smoke": smoke, "rows": 2048, "features": 16,
        "timed_steps": 16, "n_vdpus": [1, 4], "merge_every": [1, 4],
        "precisions": ["fp32"], "pipelines": ["baseline", "overlap"],
        "pipeline_precisions": ["fp32"],
    }
    if cells is None:
        cells = [
            {"n_vdpus": v, "precision": "fp32", "merge_every": k,
             "pipeline": p, "steps_per_s": 100.0}
            for v in (1, 4) for k in (1, 4)
            for p in ("baseline", "overlap")]
    return {"schema": schema, "config": config, "throughput": cells,
            "accuracy_vs_cadence": [], "accuracy_vs_pipeline": []}


class TestDiff:
    def test_identical_passes(self):
        art = _artifact()
        assert bench_diff.diff(art, art) == []

    def test_schema_mismatch_flagged(self):
        fresh = _artifact()
        committed = _artifact(schema="bench_scaling/v1")
        findings = bench_diff.diff(fresh, committed)
        assert any("schema mismatch" in f for f in findings)

    def test_missing_cell_flagged(self):
        fresh = _artifact()
        dropped = fresh["throughput"][:-1]     # lose (4, fp32, 4, overlap)
        fresh = dict(fresh, throughput=dropped)
        findings = bench_diff.diff(fresh, _artifact())
        assert any("missing throughput cell" in f for f in findings)
        assert any("pipeline=overlap" in f for f in findings)

    def test_missing_section_flagged(self):
        fresh = _artifact()
        del fresh["accuracy_vs_pipeline"]
        findings = bench_diff.diff(fresh, _artifact())
        assert any("missing section" in f for f in findings)

    def test_regression_flagged_when_comparable(self):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=10.0)   # 10x slower
        findings = bench_diff.diff(fresh, _artifact())
        assert any("regression" in f for f in findings)

    def test_small_slowdown_tolerated(self):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=60.0)   # 1.7x slower
        assert bench_diff.diff(fresh, _artifact()) == []

    def test_incomparable_configs_skip_regression(self, capsys):
        fresh = _artifact()
        fresh["throughput"][0] = dict(fresh["throughput"][0],
                                      steps_per_s=1.0)
        committed = _artifact(smoke=False)     # full-size reference
        findings = bench_diff.diff(fresh, committed)
        assert findings == []
        assert "skipped" in capsys.readouterr().out

    def test_baseline_only_precisions_not_required_to_sweep_pipelines(self):
        """int16/int8 cells only exist for the baseline pipeline; the
        completeness check must honor config.pipeline_precisions."""
        art = _artifact()
        art["config"]["precisions"] = ["fp32", "int8"]
        art["throughput"] += [
            {"n_vdpus": v, "precision": "int8", "merge_every": k,
             "pipeline": "baseline", "steps_per_s": 5.0}
            for v in (1, 4) for k in (1, 4)]
        assert bench_diff.diff(art, art) == []

    def test_cli_exit_codes(self, tmp_path, capsys):
        import json
        good = tmp_path / "good.json"
        good.write_text(json.dumps(_artifact()))
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps(_artifact(schema="bench_scaling/v1")))
        assert bench_diff.main([str(good), str(good)]) == 0
        assert bench_diff.main([str(good), str(bad)]) == 1
