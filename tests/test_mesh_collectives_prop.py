"""Hypothesis property tests: mesh collectives vs the ``mesh=None``
emulation, bit-for-bit at hop size 1 across random shapes, values,
dtypes and bit widths (the systematic sweep behind the fixed cases in
``tests/test_mesh_collectives.py``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as qz
from repro.distributed import collectives as coll
from repro.distributed import compression as comp
from repro.distributed.compression import CompressionConfig


def _hop1(fn, *args):
    stacked = jax.tree.map(lambda x: x[None], args)
    out = jax.vmap(fn, axis_name="hop")(*stacked)
    return jax.tree.map(lambda x: x[0], out)


def _bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))


def _array(seed, n, dtype, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    return (scale * x).astype(dtype)


ARRAY = dict(seed=st.integers(0, 10_000), n=st.integers(1, 128),
             dtype=st.sampled_from([jnp.float32, jnp.bfloat16,
                                    jnp.float16]),
             scale=st.sampled_from([1e-6, 1.0, 3.0, 1e4]))


@given(bits=st.integers(2, 16), **ARRAY)
@settings(max_examples=60, deadline=None)
def test_quantized_psum_ef_bit_parity(seed, n, dtype, scale, bits):
    x = _array(seed, n, dtype, scale)
    e = _array(seed + 1, n, dtype, scale * 0.1)
    got, got_err = _hop1(
        lambda v, r: coll.quantized_psum_ef(v, r, "hop", bits=bits),
        x, e)
    q, want_err = qz.ef_quantize(x, e, bits=bits)
    _bits_equal(got, q.dequantize(x.dtype))
    _bits_equal(got_err, want_err)


@given(bits=st.integers(2, 16), **ARRAY)
@settings(max_examples=40, deadline=None)
def test_quantized_psum_bit_parity(seed, n, dtype, scale, bits):
    x = _array(seed, n, dtype, scale)
    got = _hop1(lambda v: coll.quantized_psum(v, "hop", bits=bits), x)
    want = qz.quantize_symmetric(x, bits=bits).dequantize(x.dtype)
    _bits_equal(got, want)


@given(bits=st.sampled_from([None, 2, 8, 16]),
       frac=st.floats(0.05, 0.95), **ARRAY)
@settings(max_examples=60, deadline=None)
def test_sparse_psum_ef_bit_parity(seed, n, dtype, scale, bits, frac):
    x = _array(seed, n, dtype, scale)
    e = _array(seed + 1, n, dtype, scale * 0.1)
    got, got_err = _hop1(
        lambda v, r: coll.sparse_psum_ef(v, r, "hop", frac=frac,
                                         bits=bits), x, e)
    cfg = CompressionConfig(bits=bits, top_k_frac=frac)
    want, want_err = comp.ef_compress_tree({"g": x}, {"g": e}, cfg)
    _bits_equal(got, want["g"])
    _bits_equal(got_err, want_err["g"])


@given(seed=st.integers(0, 10_000), n=st.integers(1, 64),
       lim=st.sampled_from([1, 100, 2 ** 20]),
       participants=st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_integer_leaf_passthrough_is_exact(seed, n, lim, participants):
    """Integer leaves cross the compressed slow hop as exact psums —
    whatever the participant count (int32 addition is associative)."""
    rng = np.random.default_rng(seed)
    leaf = jnp.asarray(rng.integers(-lim, lim, (participants, n)),
                       jnp.int32)
    err = jnp.zeros_like(leaf)

    def reduce_fn(t, e):
        return comp.compressed_reduce(t, e, CompressionConfig(bits=8))

    got, _ = jax.vmap(jax.vmap(reduce_fn, axis_name="data"),
                      axis_name="pod")(
        {"c": leaf[:, None]}, {"c": err[:, None]})
    want = np.asarray(leaf).sum(axis=0)
    for i in range(participants):
        np.testing.assert_array_equal(np.asarray(got["c"][i, 0]), want)


@given(bits=st.integers(2, 16), **ARRAY)
@settings(max_examples=40, deadline=None)
def test_ef_never_loses_mass(seed, n, dtype, scale, bits):
    """wire + residual == target (in the promoted precision): the
    invariant that bounds compressed training O(1) from exact."""
    x = _array(seed, n, jnp.float32, scale)
    e = _array(seed + 1, n, jnp.float32, scale * 0.1)
    got, got_err = _hop1(
        lambda v, r: coll.quantized_psum_ef(v, r, "hop", bits=bits),
        x, e)
    np.testing.assert_allclose(np.asarray(got) + np.asarray(got_err),
                               np.asarray(x + e),
                               rtol=1e-5, atol=1e-5 * scale)
