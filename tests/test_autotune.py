"""Kernel block-shape autotuning (``kernels.autotune``): heuristics,
shape bucketing, the on-disk measured cache, and dispatch integration."""

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import dispatch
from repro.kernels import fxp_matmul as _fxp
from repro.kernels import kmeans_assign as _km
from repro.kernels.ops import INTERPRET


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    at.reset_cache_for_tests()
    yield path
    at.reset_cache_for_tests()


class TestBuckets:
    def test_shape_bucket_pow2(self):
        assert at.shape_bucket((300, 130, 70)) == (512, 256, 128)
        assert at.shape_bucket((1, 128)) == (1, 128)

    def test_nearby_shapes_share_keys(self):
        k1 = at.table_key("fxp_matmul", jnp.int8, (300, 130, 70), "cpu")
        k2 = at.table_key("fxp_matmul", jnp.int8, (400, 200, 100), "cpu")
        assert k1 == k2

    def test_backend_in_key(self):
        k_cpu = at.table_key("fxp_matmul", jnp.int8, (64, 64, 64), "cpu")
        k_tpu = at.table_key("fxp_matmul", jnp.int8, (64, 64, 64), "tpu")
        assert k_cpu != k_tpu


class TestHeuristics:
    def test_interpret_prefers_single_block(self):
        """interpret mode runs the kernel body per grid step in Python —
        the heuristic collapses small problems into one block."""
        b = at.block_shapes("fxp_matmul", jnp.int8, (64, 128, 32),
                            backend="cpu")
        assert b == {"block_m": 64, "block_n": 32, "block_k": 128}
        b = at.block_shapes("kmeans_assign", jnp.float32, (5000, 16, 8),
                            backend="cpu")
        assert b == {"block_n": 5000}

    def test_interpret_chunks_oversized_k(self):
        b = at.block_shapes("fxp_matmul", jnp.int8,
                            (4096, 1 << 20, 4096), backend="cpu")
        assert b["block_m"] == 4096 and b["block_n"] == 4096
        assert b["block_k"] < 1 << 20

    def test_tpu_blocks_aligned_and_capped(self):
        b = at.block_shapes("fxp_matmul", jnp.int8, (1000, 4096, 2000),
                            backend="tpu")
        # minor dims multiples of 128, int8 sublane 32, legacy caps hold
        assert b["block_n"] % 128 == 0 and b["block_k"] % 128 == 0
        assert b["block_m"] % 32 == 0
        assert b["block_m"] <= 256 and b["block_k"] <= 512

    def test_blocks_never_exceed_shape(self):
        for backend in ("cpu", "tpu"):
            b = at.block_shapes("fxp_matmul", jnp.int8, (3, 5, 2),
                                backend=backend)
            assert b["block_m"] <= 3 and b["block_k"] <= 5 \
                and b["block_n"] <= 2

    def test_split_hist_onehot_budget(self):
        """the kernel materializes a (bn, F, nodes*bins*classes) one-hot
        per step — bn must shrink as that product grows."""
        small = at.block_shapes("split_hist", jnp.float32,
                                (1 << 16, 16, 64), backend="cpu")
        big = at.block_shapes("split_hist", jnp.float32,
                              (1 << 16, 16, 1 << 14), backend="cpu")
        assert big["block_n"] < small["block_n"]


class TestMeasuredCache:
    def test_autotune_persists_and_wins(self, tmp_cache):
        best = at.autotune("fxp_matmul", (64, 128, 32))
        # on disk
        with open(tmp_cache) as f:
            data = json.load(f)
        assert len(data["entries"]) == 1
        (key, entry), = data["entries"].items()
        assert entry["blocks"] == best
        # a fresh in-memory cache reads the measured entry back
        at.reset_cache_for_tests()
        assert at.block_shapes("fxp_matmul", jnp.int16,
                               (64, 128, 32)) == best

    def test_measured_entry_clamped_to_smaller_call(self, tmp_cache):
        at._store(at.table_key("fxp_matmul", jnp.int8, (60, 120, 30)),
                  {"block_m": 64, "block_n": 32, "block_k": 128}, 1.0)
        b = at.block_shapes("fxp_matmul", jnp.int8, (40, 100, 20))
        assert b["block_m"] <= 40 and b["block_k"] <= 100 \
            and b["block_n"] <= 20

    def test_missing_cache_file_falls_back(self, tmp_cache):
        # no file written yet -> heuristic, no crash
        b = at.block_shapes("kmeans_assign", jnp.float32, (100, 4, 3))
        assert b["block_n"] == 100

    def test_corrupt_cache_ignored(self, tmp_cache):
        with open(tmp_cache, "w") as f:
            f.write("{not json")
        at.reset_cache_for_tests()
        b = at.block_shapes("fxp_matmul", jnp.int8, (8, 8, 8))
        assert b["block_m"] == 8

    def test_autotune_kmeans_smoke(self, tmp_cache):
        best = at.autotune("kmeans_assign", (256, 8, 4))
        assert 1 <= best["block_n"] <= 256

    def test_fresh_process_store_merges_disk_entries(self, tmp_cache):
        """Regression: a process whose first cache touch is autotune()
        (in-memory cache never loaded) must merge with the on-disk
        entries, not overwrite them."""
        at._store("other|int8|64x64x64|cpu",
                  {"block_m": 8, "block_n": 8, "block_k": 8}, 1.0)
        at.reset_cache_for_tests()       # simulate a fresh process
        at.autotune("kmeans_assign", (64, 4, 2))
        with open(tmp_cache) as f:
            entries = json.load(f)["entries"]
        assert "other|int8|64x64x64|cpu" in entries
        assert any(k.startswith("kmeans_assign|") for k in entries)


_RACE_WORKER = r"""
import json, os, sys
from repro.tuning import autotune as at

wid, iters = sys.argv[1], int(sys.argv[2])
for i in range(iters):
    # fresh-process view each write: reload from disk so entries merge
    at.reset_cache_for_tests()
    at._store(f"race{wid}k{i}|int8|64x64x64|cpu",
              {"block_m": 8, "block_n": 8, "block_k": 8}, float(i))
# whatever state the race left behind must parse as a complete doc
# (the other process may have won any individual entry)
with open(at.cache_path()) as f:
    assert isinstance(json.load(f)["entries"], dict)
print("ok")
"""


class TestConcurrentWriters:
    """Two processes racing ``$REPRO_AUTOTUNE_CACHE`` must never leave
    torn/invalid JSON on disk (per-writer temp file + atomic
    ``os.replace``).  Last writer may win an *entry*, but every observable
    file state is a complete document."""

    def _spawn(self, tmp_cache, wid, iters):
        env = dict(os.environ,
                   REPRO_AUTOTUNE_CACHE=tmp_cache,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__),
                                           os.pardir, "src"),
                   JAX_PLATFORMS="cpu")
        return subprocess.Popen(
            [sys.executable, "-c", _RACE_WORKER, wid, str(iters)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True)

    def test_racing_writers_never_corrupt_json(self, tmp_cache):
        procs = [self._spawn(tmp_cache, "A", 30),
                 self._spawn(tmp_cache, "B", 30)]
        reads = 0
        # poll the file while the race runs: every observable state must
        # parse (os.replace makes each publish atomic)
        while any(p.poll() is None for p in procs):
            try:
                with open(tmp_cache) as f:
                    data = json.load(f)
                assert isinstance(data.get("entries"), dict)
                reads += 1
            except FileNotFoundError:
                pass
            time.sleep(0.01)
        for p in procs:
            out, err = p.communicate(timeout=60)
            assert p.returncode == 0, f"worker failed:\n{err}"
            assert "ok" in out
        with open(tmp_cache) as f:
            entries = json.load(f)["entries"]
        # both writers reload-before-store, so entries from both sides
        # accumulate (an interleaved write may drop a handful, but the
        # survivor set is well-formed and from the expected key space)
        assert entries, "race left an empty cache"
        for key, entry in entries.items():
            assert key.startswith("race"), key
            assert set(entry["blocks"]) == {"block_m", "block_n",
                                            "block_k"}
        assert any(k.startswith("raceA") for k in entries) \
            or any(k.startswith("raceB") for k in entries)
        # no temp droppings left behind
        leftovers = [f for f in os.listdir(os.path.dirname(tmp_cache))
                     if f.endswith(".tmp")]
        assert leftovers == []

    def test_sequential_processes_merge_entries(self, tmp_cache):
        """The cross-process flavor of fresh-process merging: writer B
        starting after writer A finished must keep A's entries."""
        a = self._spawn(tmp_cache, "A", 2)
        assert a.wait(timeout=120) == 0, a.communicate()[1]
        b = self._spawn(tmp_cache, "B", 2)
        assert b.wait(timeout=120) == 0, b.communicate()[1]
        with open(tmp_cache) as f:
            entries = json.load(f)["entries"]
        assert "raceA" + "k1|int8|64x64x64|cpu" in entries
        assert "raceB" + "k1|int8|64x64x64|cpu" in entries


class TestResetIsolation:
    """``reset_cache_for_tests`` audit: what the in-memory cache keys on
    and what actually needs a reset."""

    def test_env_repoint_is_keyed_without_reset(self, tmp_cache,
                                                tmp_path, monkeypatch):
        """The in-memory cache is keyed on the resolved path, so merely
        repointing $REPRO_AUTOTUNE_CACHE is honored without a reset —
        a test that forgets the fixture cannot serve another path's
        entries."""
        at._store(at.table_key("fxp_matmul", jnp.int8, (60, 120, 30),
                               "cpu"),
                  {"block_m": 2, "block_n": 2, "block_k": 2}, 1.0)
        other = str(tmp_path / "other_cache.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", other)
        b = at.block_shapes("fxp_matmul", jnp.int8, (60, 120, 30),
                            backend="cpu")
        assert b != {"block_m": 2, "block_n": 2, "block_k": 2}

    def test_same_path_mutation_needs_reset(self, tmp_cache):
        """Mutating the file *under the same path* is what the reset is
        for: the loaded dict is cached until reset drops it."""
        key = at.table_key("fxp_matmul", jnp.int8, (60, 120, 30), "cpu")
        at.block_shapes("fxp_matmul", jnp.int8, (60, 120, 30),
                        backend="cpu")          # loads (empty) cache
        with open(tmp_cache, "w") as f:
            json.dump({"version": 1, "entries": {key: {
                "blocks": {"block_m": 4, "block_n": 4, "block_k": 4},
                "us": 1.0}}}, f)
        stale = at.block_shapes("fxp_matmul", jnp.int8, (60, 120, 30),
                                backend="cpu")
        assert stale["block_m"] != 4            # still the loaded view
        at.reset_cache_for_tests()
        fresh = at.block_shapes("fxp_matmul", jnp.int8, (60, 120, 30),
                                backend="cpu")
        assert fresh == {"block_m": 4, "block_n": 4, "block_k": 4}

    def test_store_after_reset_does_not_resurrect_memory(self, tmp_cache):
        """After a reset, _store must rebuild its view from disk — the
        dropped in-memory entries must not leak back in."""
        at._store("ghost|int8|8x8x8|cpu",
                  {"block_m": 1, "block_n": 1, "block_k": 1}, 1.0)
        os.remove(tmp_cache)                    # disk truth: nothing
        at.reset_cache_for_tests()
        at._store("real|int8|8x8x8|cpu",
                  {"block_m": 2, "block_n": 2, "block_k": 2}, 1.0)
        with open(tmp_cache) as f:
            entries = json.load(f)["entries"]
        assert set(entries) == {"real|int8|8x8x8|cpu"}


class TestKernelsUnderTunedBlocks:
    """Any block shape the table hands out must stay numerically exact —
    padding/tiling is an implementation detail."""

    def test_fxp_matmul_odd_shapes(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-100, 100, (33, 57)), jnp.int8)
        b = jnp.asarray(rng.integers(-100, 100, (57, 19)), jnp.int8)
        want = np.asarray(a, np.int32) @ np.asarray(b, np.int32)
        for blocks in ({"block_m": 33, "block_n": 19, "block_k": 57},
                       {"block_m": 8, "block_n": 8, "block_k": 16},
                       {"block_m": 16, "block_n": 4, "block_k": 57}):
            out = _fxp.fxp_matmul(a, b, interpret=True, **blocks)
            np.testing.assert_array_equal(np.asarray(out), want)

    def test_kmeans_assign_block_sweep(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(100, 5)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
        w = jnp.ones((100,), jnp.float32)
        ref = _km.kmeans_assign(x, c, w, interpret=True, block_n=100)
        for bn in (7, 32, 64):
            out = _km.kmeans_assign(x, c, w, interpret=True, block_n=bn)
            for r, o in zip(ref, out):
                np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                           rtol=1e-5, atol=1e-5)

    def test_dispatch_parity_with_table(self, tmp_cache):
        """hybrid_matmul through the (heuristic) table still matches the
        pure-jnp reference bit-for-bit."""
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.integers(-30000, 30000, (47, 83)), jnp.int16)
        b = jnp.asarray(rng.integers(-30000, 30000, (83, 11)), jnp.int16)
        out = dispatch.hybrid_matmul(a, b)
        with dispatch.use_kernels(False):
            ref = dispatch.hybrid_matmul(a, b)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
