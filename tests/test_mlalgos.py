"""End-to-end correctness of the paper's four workloads, including the
fixed-point / LUT variants (the paper's accuracy-parity claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import (train_linreg, train_logreg, train_kmeans,
                                train_dtree)
from repro.core.mlalgos.linreg import closed_form
from repro.core.mlalgos.logreg import accuracy
from repro.core.mlalgos.dtree import dtree_predict
from repro.core.mlalgos.kmeans import kmeans_assign_points

KEY = jax.random.PRNGKey(0)
GRID = make_cpu_grid(16)


class TestLinReg:
    @pytest.fixture(scope="class")
    def data(self):
        return datasets.regression(KEY, 2000, 16)

    def test_fp32_matches_closed_form(self, data):
        X, y, _ = data
        res = train_linreg(GRID, X, y, lr=0.05, steps=200)
        w_cf = closed_form(X, y)
        assert float(jnp.max(jnp.abs(res.w - w_cf))) < 5e-3

    @pytest.mark.parametrize("precision,tol", [("int16", 5e-3),
                                               ("int8", 5e-2)])
    def test_fixed_point_parity(self, data, precision, tol):
        """Paper claim: hybrid-precision training loses ~no accuracy."""
        X, y, _ = data
        res = train_linreg(GRID, X, y, lr=0.05, steps=200,
                           precision=precision)
        w_cf = closed_form(X, y)
        assert float(jnp.max(jnp.abs(res.w - w_cf))) < tol

    def test_loss_monotone_decreasing(self, data):
        X, y, _ = data
        res = train_linreg(GRID, X, y, lr=0.02, steps=50)
        losses = [float(h["loss"]) for h in res.history]
        assert losses[-1] < losses[0]
        assert all(b <= a * 1.001 for a, b in zip(losses, losses[1:]))


class TestLogReg:
    @pytest.fixture(scope="class")
    def data(self):
        return datasets.binary_classification(KEY, 3000, 12)

    def test_lut_matches_exact_sigmoid(self, data):
        """Paper claim: LUT sigmoid == exact sigmoid for training."""
        X, y, _ = data
        r_exact = train_logreg(GRID, X, y, lr=0.5, steps=120,
                               sigmoid="exact")
        r_lut = train_logreg(GRID, X, y, lr=0.5, steps=120, sigmoid="lut")
        a_exact, a_lut = accuracy(r_exact.w, X, y), accuracy(r_lut.w, X, y)
        assert abs(a_exact - a_lut) < 0.01
        assert a_exact > 0.75

    def test_int8_lut_parity(self, data):
        X, y, _ = data
        r = train_logreg(GRID, X, y, lr=0.5, steps=120, precision="int8",
                         sigmoid="lut")
        assert accuracy(r.w, X, y) > 0.75

    def test_taylor_degrades(self, data):
        """Paper claim: Taylor-series sigmoid hurts training."""
        X, y, _ = data
        r_t = train_logreg(GRID, X, y, lr=0.5, steps=120, sigmoid="taylor")
        r_e = train_logreg(GRID, X, y, lr=0.5, steps=120, sigmoid="exact")
        assert accuracy(r_t.w, X, y) < accuracy(r_e.w, X, y) - 0.05


class TestKMeans:
    def test_sse_monotone_and_recovers_blobs(self):
        X, assign, centers = datasets.blobs(KEY, 3000, 6, k=4, spread=0.2)
        res = train_kmeans(GRID, X, 4, iters=15)
        sses = [float(h["sse"]) for h in res.history]
        assert all(b <= a * 1.0001 for a, b in zip(sses, sses[1:]))
        # every true center has a learned centroid nearby
        d = jnp.linalg.norm(res.centroids[:, None] - centers[None],
                            axis=-1)
        assert float(jnp.max(jnp.min(d, axis=0))) < 0.5

    def test_int8_parity(self):
        X, _, _ = datasets.blobs(KEY, 3000, 6, k=4, spread=0.2)
        r32 = train_kmeans(GRID, X, 4, iters=12)
        r8 = train_kmeans(GRID, X, 4, iters=12, precision="int8")
        sse32 = float(r32.history[-1]["sse"])
        sse8 = float(r8.history[-1]["sse"])
        assert sse8 < sse32 * 1.05

    def test_assignment_function(self):
        X, _, centers = datasets.blobs(KEY, 500, 4, k=3, spread=0.1)
        a = kmeans_assign_points(centers, X)
        assert a.shape == (500,)
        assert int(jnp.max(a)) <= 2


class TestDTree:
    def test_fits_separable_mixture(self):
        X, y = datasets.mixture_classification(KEY, 3000, 8, n_classes=3)
        res = train_dtree(GRID, X, y, max_depth=6, n_bins=32, n_classes=3)
        pred = dtree_predict(res.tree, X)
        acc = float(jnp.mean(pred == y))
        assert acc > 0.9

    def test_depth_zero_safety(self):
        X, y = datasets.mixture_classification(KEY, 200, 4, n_classes=2)
        res = train_dtree(GRID, X, y, max_depth=1, n_bins=8, n_classes=2)
        pred = dtree_predict(res.tree, X)
        assert pred.shape == (200,)

    def test_pure_labels_stop_splitting(self):
        X = jax.random.normal(KEY, (256, 4))
        y = jnp.zeros((256,), jnp.int32)       # one class: no valid split
        res = train_dtree(GRID, X, y, max_depth=4, n_bins=8, n_classes=2)
        pred = dtree_predict(res.tree, X)
        assert int(jnp.sum(pred)) == 0
        assert res.history[0]["splits"] == 0
