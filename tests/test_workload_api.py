"""The Workload subsystem (``core.mlalgos.api``): protocol bit-exactness
for the four ported estimators, capability-flag degradation, compile-
cache stability of bound Programs, the two new PIM-Opt estimators
(linear SVM, multinomial logreg) against numpy oracles under cadence
and minibatching, and the Trainer integration."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import (api, WORKLOADS, LinReg, LogReg, KMeans,
                                DecisionTree, LinearSVM,
                                MultinomialLogReg, train_linreg,
                                train_svm, train_multinomial)
from repro.core.mlalgos.svm import svm_accuracy
from repro.core.mlalgos.multinomial import multinomial_accuracy
from repro.distributed.merge_plan import (MergePlan, SlowMo,
                                          MergeFallbackWarning)
from repro.runtime import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


class TestProtocolBitExactness:
    """batch_size=None through api.fit must be bit-exact with the
    python-engine oracle (i.e. with the PR 4 engine) for every ported
    workload."""

    def test_linreg(self):
        X, y, _ = datasets.regression(KEY, 400, 8)
        grid = make_cpu_grid(8)
        r = api.fit(LinReg(lr=0.05), grid, X, y, steps=40)
        r_py = api.fit(LinReg(lr=0.05), grid, X, y, steps=40,
                       engine="python")
        np.testing.assert_array_equal(np.asarray(r.state),
                                      np.asarray(r_py.state))

    def test_logreg(self):
        X, y, _ = datasets.binary_classification(KEY, 400, 6)
        grid = make_cpu_grid(8)
        wl = LogReg(lr=0.5, sigmoid="lut")
        r = api.fit(wl, grid, X, y, steps=30)
        r_py = api.fit(wl, grid, X, y, steps=30, engine="python")
        np.testing.assert_array_equal(np.asarray(r.state),
                                      np.asarray(r_py.state))

    def test_kmeans(self):
        X, _, _ = datasets.blobs(KEY, 500, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r = api.fit(KMeans(k=3), grid, X, steps=8)
        r_py = api.fit(KMeans(k=3), grid, X, steps=8, engine="python")
        np.testing.assert_array_equal(np.asarray(r.state),
                                      np.asarray(r_py.state))

    def test_svm(self):
        X, y, _ = datasets.binary_classification(KEY, 400, 6)
        grid = make_cpu_grid(8)
        r = api.fit(LinearSVM(lr=0.1), grid, X, y, steps=30)
        r_py = api.fit(LinearSVM(lr=0.1), grid, X, y, steps=30,
                       engine="python")
        np.testing.assert_array_equal(np.asarray(r.state),
                                      np.asarray(r_py.state))

    def test_multinomial(self):
        X, y = datasets.mixture_classification(KEY, 400, 6, 3)
        grid = make_cpu_grid(8)
        wl = MultinomialLogReg(n_classes=3)
        r = api.fit(wl, grid, X, y, steps=30)
        r_py = api.fit(wl, grid, X, y, steps=30, engine="python")
        np.testing.assert_array_equal(np.asarray(r.state),
                                      np.asarray(r_py.state))

    def test_dtree_via_api_matches_wrapper(self):
        from repro.core.mlalgos.dtree import dtree_predict, train_dtree
        X, y = datasets.mixture_classification(KEY, 600, 6, 2)
        grid = make_cpu_grid(8)
        r_api = api.fit(DecisionTree(max_depth=3), grid, X, y, steps=3)
        r_wrap = train_dtree(grid, X, y, max_depth=3)
        np.testing.assert_array_equal(
            np.asarray(dtree_predict(r_api.state, X)),
            np.asarray(dtree_predict(r_wrap.tree, X)))


class TestMergeCaps:
    def test_default_supports_everything(self):
        caps = api.MergeCaps()
        plan = MergePlan(cadence=4, overlap=True)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            out_plan, bs = caps.constrain("x", plan, 16)
        assert out_plan is plan and bs == 16

    def test_exact_only_degrades_everything_with_one_warning(self):
        caps = api.MergeCaps.exact_only("discrete commits")
        plan = MergePlan(cadence=4, overlap=True, outer=SlowMo())
        with pytest.warns(MergeFallbackWarning) as rec:
            out_plan, bs = caps.constrain("dtree", plan, 16)
        assert len(rec) == 1
        msg = str(rec[0].message)
        assert "merge_every=4" in msg and "overlap_merge" in msg
        assert "outer=SlowMo" in msg and "batch_size=16" in msg
        assert out_plan.is_exact_default and out_plan.cadence == 1
        assert bs is None

    def test_dtree_minibatch_degrades(self):
        X, y = datasets.mixture_classification(KEY, 400, 4, 2)
        grid = make_cpu_grid(4)
        with pytest.warns(MergeFallbackWarning, match="batch_size"):
            api.fit(DecisionTree(max_depth=2), grid, X, y, steps=2,
                    batch_size=8)


class TestProgramCaching:
    def test_repeated_fit_on_program_reuses_runner(self):
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        program = LinReg(lr=0.05).bind(grid, X, y)
        program.fit(steps=4)
        n0 = len(grid._fit_cache)
        program.fit(steps=4)
        program.fit(steps=4, batch_size=8)
        n1 = len(grid._fit_cache)
        program.fit(steps=4, batch_size=8)
        assert len(grid._fit_cache) == n1 > n0

    def test_rebinding_equal_config_shares_runner(self):
        """Two equal fp32 estimator configs (hashable dataclass +
        primitive consts) must share a compiled runner across binds —
        the train_* rebuild-per-call pattern."""
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        LinReg(lr=0.05).bind(grid, X, y).fit(steps=4)
        n0 = len(grid._fit_cache)
        LinReg(lr=0.05).bind(grid, X, y).fit(steps=4)
        assert len(grid._fit_cache) == n0

    def test_different_hyperparams_do_not_collide(self):
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        r1 = api.fit(LinReg(lr=0.1), grid, X, y, steps=30)
        r2 = api.fit(LinReg(lr=0.01), grid, X, y, steps=30)
        assert float(jnp.max(jnp.abs(r1.state - r2.state))) > 1e-6

    def test_fit_result_eval(self):
        X, y, _ = datasets.binary_classification(KEY, 400, 6)
        grid = make_cpu_grid(4)
        res = api.fit(LogReg(lr=0.5), grid, X, y, steps=40)
        assert 0.5 <= res.eval(X, y)["accuracy"] <= 1.0


def _svm_oracle(X, y, lr, l2, steps):
    """Full-batch hinge subgradient descent, global-sum formulation."""
    ys = np.where(np.asarray(y) > 0, 1.0, -1.0).astype(np.float32)
    n, d = X.shape
    w = np.zeros((d,), np.float32)
    for _ in range(steps):
        z = X @ w
        active = ((ys * z) < 1.0).astype(np.float32)
        g = X.T @ (-(ys * active))
        w = (w - lr * (g / n + l2 * w)).astype(np.float32)
    return w


def _multinomial_oracle(X, y, C, lr, steps):
    n, d = X.shape
    W = np.zeros((d, C), np.float32)
    onehot = np.eye(C, dtype=np.float32)[np.asarray(y)]
    for _ in range(steps):
        Z = X @ W
        Z = Z - Z.max(axis=1, keepdims=True)
        P = np.exp(Z)
        P /= P.sum(axis=1, keepdims=True)
        G = X.T @ (P - onehot)
        W = (W - lr * G / n).astype(np.float32)
    return W


class TestLinearSVM:
    def _data(self):
        return datasets.binary_classification(KEY, 2048, 10)

    def test_matches_numpy_oracle(self):
        X, y, _ = self._data()
        grid = make_cpu_grid(8)
        res = train_svm(grid, X, y, lr=0.1, l2=1e-3, steps=100)
        w_o = _svm_oracle(np.asarray(X), np.asarray(y), 0.1, 1e-3, 100)
        np.testing.assert_allclose(np.asarray(res.w), w_o, rtol=1e-3,
                                   atol=1e-4)

    def test_oracle_matching_accuracy_cadence_minibatch(self):
        """The acceptance grid: MergePlan cadence {1,4} x minibatch —
        the trained model must match the full-batch oracle's accuracy
        to within 2 points."""
        X, y, _ = self._data()
        grid = make_cpu_grid(8)
        w_o = _svm_oracle(np.asarray(X), np.asarray(y), 0.1, 1e-3, 150)
        acc_o = svm_accuracy(jnp.asarray(w_o), X, y)
        for k in (1, 4):
            res = train_svm(grid, X, y, lr=0.1, l2=1e-3, steps=150,
                            merge_plan=MergePlan(cadence=k),
                            batch_size=64)
            acc = svm_accuracy(res.w, X, y)
            assert acc >= acc_o - 0.02, (k, acc, acc_o)

    def test_int8_parity(self):
        X, y, _ = self._data()
        grid = make_cpu_grid(8)
        r32 = train_svm(grid, X, y, lr=0.1, steps=100)
        r8 = train_svm(grid, X, y, lr=0.1, steps=100, precision="int8")
        a32, a8 = svm_accuracy(r32.w, X, y), svm_accuracy(r8.w, X, y)
        assert abs(a32 - a8) < 0.02

    def test_pm1_labels_accepted(self):
        X, y, _ = self._data()
        ypm = jnp.where(y > 0, 1.0, -1.0)
        grid = make_cpu_grid(8)
        r01 = train_svm(grid, X, y, lr=0.1, steps=40)
        rpm = train_svm(grid, X, ypm, lr=0.1, steps=40)
        np.testing.assert_array_equal(np.asarray(r01.w),
                                      np.asarray(rpm.w))


class TestMultinomial:
    def _data(self, C=4):
        return datasets.mixture_classification(KEY, 2048, 10, C)

    def test_matches_numpy_oracle(self):
        X, y = self._data()
        grid = make_cpu_grid(8)
        res = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                                steps=80)
        W_o = _multinomial_oracle(np.asarray(X), np.asarray(y), 4, 0.5,
                                  80)
        np.testing.assert_allclose(np.asarray(res.W), W_o, rtol=1e-3,
                                   atol=1e-4)

    def test_oracle_matching_accuracy_cadence_minibatch(self):
        X, y = self._data()
        grid = make_cpu_grid(8)
        W_o = _multinomial_oracle(np.asarray(X), np.asarray(y), 4, 0.5,
                                  120)
        acc_o = multinomial_accuracy(jnp.asarray(W_o), X, y)
        for k in (1, 4):
            res = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                                    steps=120,
                                    merge_plan=MergePlan(cadence=k),
                                    batch_size=64)
            acc = multinomial_accuracy(res.W, X, y)
            assert acc >= acc_o - 0.02, (k, acc, acc_o)

    def test_lut_softmax_parity(self):
        """Insight I2 for the C-class case: the exp-LUT softmax costs
        ~no accuracy vs the exact softmax."""
        X, y = self._data()
        grid = make_cpu_grid(8)
        r_e = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                                steps=80, softmax="exact")
        r_l = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                                steps=80, softmax="lut")
        a_e = multinomial_accuracy(r_e.W, X, y)
        a_l = multinomial_accuracy(r_l.W, X, y)
        assert abs(a_e - a_l) < 0.01
        assert a_e > 0.8

    def test_int8_parity(self):
        X, y = self._data()
        grid = make_cpu_grid(8)
        r32 = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                                steps=60)
        r8 = train_multinomial(grid, X, y, n_classes=4, lr=0.5,
                               steps=60, precision="int8")
        a32 = multinomial_accuracy(r32.W, X, y)
        a8 = multinomial_accuracy(r8.W, X, y)
        assert abs(a32 - a8) < 0.03

    def test_two_classes_agrees_with_binary_logreg_direction(self):
        """Sanity: C=2 multinomial separates like the binary model."""
        X, y, _ = datasets.binary_classification(KEY, 1024, 8)
        grid = make_cpu_grid(8)
        res = train_multinomial(grid, X, y.astype(jnp.int32),
                                n_classes=2, lr=0.5, steps=80)
        assert multinomial_accuracy(res.W, X, y) > 0.7


class TestRegistryAndConfig:
    def test_workload_registry_complete(self):
        assert set(WORKLOADS) == {"linreg", "logreg", "kmeans", "dtree",
                                  "svm", "multinomial"}
        for cls in WORKLOADS.values():
            assert issubclass(cls, api.Workload)

    def test_config_workload_spec(self):
        from repro.configs.pim_ml import PimMLConfig
        wl = PimMLConfig(workload="svm").workload_spec()
        assert isinstance(wl, LinearSVM)
        wl = PimMLConfig(workload="multinomial").workload_spec("int8")
        assert isinstance(wl, MultinomialLogReg)
        assert wl.softmax == "lut"
        with pytest.raises(ValueError, match="workload"):
            PimMLConfig(workload="nope").workload_spec()

    def test_spec_fns_lowerable(self):
        """The dryrun path: spec-level fns trace over ShapeDtypeStructs
        without any resident data."""
        for wl in (LogReg(precision="int8", sigmoid="lut"),
                   LinearSVM(precision="int8"),
                   MultinomialLogReg(n_classes=4, precision="int8",
                                     softmax="lut")):
            lf, uf, s0 = wl.spec_fns(features=8, rows=64)
            sl = {"X": jax.ShapeDtypeStruct((16, 8), jnp.int8),
                  "y0": jax.ShapeDtypeStruct(
                      (16,), jnp.int32 if wl.name == "multinomial"
                      else jnp.float32),
                  "w": jax.ShapeDtypeStruct((16,), jnp.float32)}
            part = jax.eval_shape(lf, s0, sl)
            out = jax.eval_shape(uf, s0, part)
            assert jax.tree.map(lambda x: x.shape, out[0]) \
                == jax.tree.map(lambda x: x.shape,
                                jax.eval_shape(lambda: s0))


class TestTrainerIntegration:
    def test_for_program_runs_and_resumes(self, tmp_path):
        X, y, _ = datasets.regression(KEY, 512, 8)
        grid = make_cpu_grid(8)
        program = LinReg(lr=0.05).bind(grid, X, y)
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10,
                            log_every=5, batch_size=16)
        tr = Trainer.for_program(program, cfg)
        tr.run(30)
        assert float(tr.state[1]) == 30.0       # sampler counter rides
        tr2 = Trainer.for_program(program, cfg)  # resume from ckpt
        assert tr2.start_step == 30
        assert float(tr2.state[1]) == 30.0      # schedule position too

    def test_for_program_full_batch_matches_fit(self):
        X, y, _ = datasets.regression(KEY, 512, 8)
        grid = make_cpu_grid(8)
        program = LinReg(lr=0.05).bind(grid, X, y)
        tr = Trainer.for_program(program)
        tr.run(20)
        res = program.fit(steps=20)
        np.testing.assert_allclose(np.asarray(tr.state),
                                   np.asarray(res.state), rtol=1e-6)

    def test_for_program_cadence_matches_fit(self):
        """Cadence plans drive round-granular dispatch: bit-compatible
        final state with api.fit at the same cadence, one history entry
        per local step (remainder round included)."""
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        program = LinReg(lr=0.05).bind(grid, X, y)
        tr = Trainer.for_program(program, TrainerConfig(merge_every=4))
        out = tr.run(10)                 # 2 full rounds + remainder 2
        res = program.fit(steps=10, merge_every=4)
        np.testing.assert_allclose(np.asarray(tr.state),
                                   np.asarray(res.state), rtol=1e-6)
        assert [e["step"] for e in out["history"]] == list(range(10))
        assert all("loss" in e for e in out["history"])

    def test_for_program_cadence_ckpt_on_merge_boundary(self, tmp_path):
        """ckpt_every that lands mid-round defers to the next merge
        boundary; resume restores the deferred step."""
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        program = LinReg(lr=0.05).bind(grid, X, y)
        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                            merge_plan=MergePlan(cadence=4),
                            log_every=100)
        tr = Trainer.for_program(program, cfg)
        tr.run(16)       # merge boundaries at steps 3, 7, 11, 15
        steps = {int(p.name.split("_")[1])
                 for p in tmp_path.iterdir() if p.name.startswith("step_")}
        assert steps and all((s + 1) % 4 == 0 for s in steps)
        tr2 = Trainer.for_program(program, cfg)
        assert tr2.start_step == 16

    def test_for_program_refuses_pipeline_plans(self):
        from repro.distributed.compression import CompressionConfig
        X, y, _ = datasets.regression(KEY, 256, 6)
        grid = make_cpu_grid(4)
        program = LinReg(lr=0.05).bind(grid, X, y)
        for plan in (MergePlan(cadence=2,
                               compression=CompressionConfig(bits=8)),
                     MergePlan(outer=SlowMo()),
                     "auto"):
            with pytest.raises(ValueError, match="exact merge rounds"):
                Trainer.for_program(
                    program, TrainerConfig(merge_plan=plan))
