"""data/pipeline primitives: TokenStream determinism, the hardened
Prefetcher lifecycle, and PartitionRotation's window materialization
(shapes, placement, schedule purity, epoch coverage)."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import make_cpu_grid
from repro.data import Prefetcher, StreamingDataset, TokenStream


class TestTokenStream:
    def test_batch_at_resume_exact(self):
        """``batch_at(step)`` is pure in (seed, step) — a restarted
        stream replays the same tokens (fault-tolerant resume)."""
        a = TokenStream(vocab_size=64, batch=4, seq_len=16, seed=11)
        b = TokenStream(vocab_size=64, batch=4, seq_len=16, seed=11)
        for step in (0, 1, 7, 123):
            np.testing.assert_array_equal(a.batch_at(step)["tokens"],
                                          b.batch_at(step)["tokens"])

    def test_iter_matches_batch_at(self):
        ts = TokenStream(vocab_size=32, batch=2, seq_len=8, seed=3)
        it = iter(ts)
        for step in range(4):
            np.testing.assert_array_equal(next(it)["tokens"],
                                          ts.batch_at(step)["tokens"])

    def test_seeds_differ(self):
        a = TokenStream(vocab_size=64, batch=4, seq_len=16, seed=0)
        b = TokenStream(vocab_size=64, batch=4, seq_len=16, seed=1)
        assert not np.array_equal(a.batch_at(0)["tokens"],
                                  b.batch_at(0)["tokens"])


class TestPrefetcher:
    def test_preserves_order(self):
        for depth in (1, 2, 5):
            pf = Prefetcher(iter(range(50)), depth=depth)
            assert list(pf) == list(range(50))
            pf.close()

    def test_transform_runs_on_worker(self):
        pf = Prefetcher(iter(range(8)), depth=2,
                        transform=lambda x: x * 10)
        assert list(pf) == [i * 10 for i in range(8)]
        pf.close()

    def test_exhaustion_is_sticky(self):
        """After the source ends, every further ``next`` raises
        StopIteration — the consumer can't hang on a dead worker."""
        pf = Prefetcher(iter(range(2)), depth=2)
        assert list(pf) == [0, 1]
        with pytest.raises(StopIteration):
            next(pf)
        with pytest.raises(StopIteration):
            next(pf)
        pf.close()

    def test_depth_validated(self):
        with pytest.raises(ValueError, match="depth"):
            Prefetcher(iter(range(2)), depth=0)

    def test_close_with_full_queue_does_not_deadlock(self):
        """The regression this hardening exists for: an infinite source
        fills the queue, the worker blocks in ``put``, and ``close``
        must still stop + join it (the old blocking put deadlocked)."""
        def infinite():
            i = 0
            while True:
                yield i
                i += 1

        pf = Prefetcher(infinite(), depth=1)
        assert next(pf) == 0               # worker alive and producing
        t0 = time.perf_counter()
        pf.close()
        assert time.perf_counter() - t0 < 5.0
        assert not pf._thread.is_alive()

    def test_next_after_close_raises(self):
        pf = Prefetcher(iter(range(100)), depth=1)
        pf.close()
        with pytest.raises(RuntimeError, match="closed"):
            next(pf)

    def test_close_idempotent(self):
        pf = Prefetcher(iter(range(3)), depth=1)
        pf.close()
        pf.close()

    def test_close_wakes_blocked_consumer_worker(self):
        """A consumer blocked in ``__next__`` while the worker waits on
        a slow source is released when ``close`` re-primes the
        sentinel (no hang on shutdown mid-stall)."""
        release = threading.Event()

        def slow():
            yield 0
            release.wait(timeout=30)
            yield 1

        pf = Prefetcher(slow(), depth=1)
        assert next(pf) == 0
        got = []

        def consume():
            try:
                got.append(next(pf))
            except (StopIteration, RuntimeError) as e:
                got.append(type(e).__name__)

        t = threading.Thread(target=consume, daemon=True)
        t.start()
        time.sleep(0.05)                   # let it block in get()
        release.set()                      # un-stick the source for join
        pf.close()
        t.join(timeout=5.0)
        assert not t.is_alive()
        assert len(got) == 1

    def test_timing_lists_recorded(self):
        pf = Prefetcher(iter(range(6)), depth=2)
        list(pf)
        assert len(pf.produce_s) == 6
        assert len(pf.stall_s) == 6
        assert all(s >= 0 for s in pf.produce_s + pf.stall_s)
        pf.close()


class TestStreamingDatasetValidation:
    def test_row_mismatch(self):
        with pytest.raises(ValueError, match="rows"):
            StreamingDataset(np.ones((10, 2), np.float32),
                             np.ones(9, np.float32), partition_rows=4)

    def test_partition_rows_validated(self):
        with pytest.raises(ValueError, match="partition_rows"):
            StreamingDataset(np.ones((10, 2), np.float32),
                             partition_rows=0)

    def test_absmax_matches_global_reduction(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(257, 5)).astype(np.float32)
        y = rng.normal(size=257).astype(np.float32)
        sd = StreamingDataset(X, y, partition_rows=64)
        np.testing.assert_array_equal(
            sd.feature_absmax(block_rows=100),
            np.abs(X).max(axis=0, keepdims=True))
        assert sd.label_absmax(block_rows=100) == np.abs(y).max()


class TestPartitionRotation:
    def _rotation(self, n=100, d=3, part_rows=32, nv=4, **kw):
        rng = np.random.default_rng(1)
        X = rng.normal(size=(n, d)).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        sd = StreamingDataset(X, y, partition_rows=part_rows, **kw)
        return sd.bind(make_cpu_grid(nv))

    def test_window_shapes_and_placement(self):
        rot = self._rotation()
        data = rot.window_data(0)
        nv, part = rot.grid.n_vdpus, rot.part
        assert set(data) == {"X", "w", "y0", "scale"}
        assert data["X"].shape == (nv, part, 3)
        assert data["w"].shape == (nv, part)
        assert data["scale"].shape == (nv,)
        assert all(isinstance(leaf, jax.Array)
                   for leaf in jax.tree.leaves(data))

    def test_window_host_pure_in_t(self):
        rot = self._rotation()
        a, b = rot.window_host(3), rot.window_host(3)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))

    def test_epoch_coverage_exact(self):
        """One epoch of windows visits every real resident slot exactly
        once (the sampler's coverage proof, lifted to the host) and
        never the pad slots."""
        rot = self._rotation(n=100, nv=4, part_rows=32)
        per, nv = rot.per, rot.grid.n_vdpus
        # every real slot once; pads (rows >= n in the tail vDPU) never
        slot_rows = (np.arange(nv)[:, None] * per + np.arange(per)[None])
        expect = (slot_rows < 100).astype(np.float64)
        visits = np.zeros((nv, per))
        for t in range(rot.windows_per_epoch):
            idx, _ = rot.schedule(t)
            host = rot.window_host(t)
            visits[:, idx] += np.asarray(host["w"])
        np.testing.assert_array_equal(visits, expect)

    def test_exact_full_single_window(self):
        """partition >= dataset => one all-ones window, scale omitted
        (the driver then reuses the resident compiled graph as-is)."""
        rot = self._rotation(n=100, nv=4, part_rows=100)
        assert rot.exact_full and rot.windows_per_epoch == 1
        host = rot.window_host(0)
        assert "scale" not in host

    def test_prefetcher_matches_synchronous_windows(self):
        rot = self._rotation()
        pf = rot.prefetcher(0, depth=2)
        try:
            for t in range(3):
                sync = rot.window_data(t)
                pre = next(pf)
                for k in sync:
                    np.testing.assert_array_equal(np.asarray(sync[k]),
                                                  np.asarray(pre[k]))
        finally:
            pf.close()

    def test_pad_rows_zeroed(self):
        rot = self._rotation(n=10, nv=4, part_rows=12, shuffle=False)
        host = rot.window_host(rot.windows_per_epoch - 1)
        w = np.asarray(host["w"])
        X = np.asarray(host["X"])
        assert (X[w == 0.0] == 0.0).all()

    def test_schedule_cache_bounded(self):
        rot = self._rotation()
        rot.prewarm_schedules(range(5000))
        assert len(rot._sched_cache) <= 4096


class TestQuantizedStaging:
    """The per-window numpy quantization path (satellite of the serving
    PR): ``quantize_fixed_scale_np`` must be bit-identical to the jnp
    ``quantize_fixed_scale`` it mirrors, and an int8 workload's staged
    windows must hold the narrow integers (halved H2D bytes) produced
    without any JAX dispatch on the Prefetcher worker."""

    @pytest.mark.parametrize("bits", [8, 16])
    def test_np_mirror_bit_parity(self, bits):
        from repro.core import quantize as qz
        rng = np.random.default_rng(7)
        x = (rng.normal(size=(257, 9)) * 3).astype(np.float32)
        scale = qz.symmetric_scale(
            np.abs(x).max(axis=0, keepdims=True), bits)
        # exact .5 ties on the quantized grid: round-half-even must
        # agree between numpy and XLA
        x[:5] = np.asarray(scale)[0] * np.array(
            [0.5, 1.5, -0.5, -2.5, 7.5], np.float32)[:, None]
        ref = np.asarray(qz.quantize_fixed_scale(x, scale, bits).values)
        got = qz.quantize_fixed_scale_np(x, scale, bits)
        assert got.dtype == ref.dtype
        np.testing.assert_array_equal(got, ref)

    def test_int8_workload_stages_narrow_windows(self):
        from repro.core.mlalgos.linreg import LinReg

        rng = np.random.default_rng(3)
        X = rng.normal(size=(96, 5)).astype(np.float32)
        y = rng.normal(size=96).astype(np.float32)
        sd = StreamingDataset(X, y, partition_rows=32, shuffle=False)
        grid = make_cpu_grid(4)
        prog = LinReg(lr=0.05, precision="int8").bind_stream(grid, sd)
        host = prog.data.window_host(0)
        # staged representation is the quantized one: int8 features,
        # int16 labels, produced as plain numpy (no JAX execution on
        # the worker thread)
        assert host["X"].dtype == np.int8
        assert host["y0"].dtype == np.int16
        assert isinstance(host["X"], np.ndarray)
        assert not isinstance(host["X"], jax.Array)
        # bit-parity with the jnp staging the transform replaces:
        # reconstruct window 0's gather (slot (v, i) -> row v*per+idx[i])
        from repro.core import quantize as qz
        rot = prog.data
        idx, _ = rot.schedule(0)
        flat = (np.arange(rot.grid.n_vdpus)[:, None] * rot.per
                + np.asarray(idx)[None, :]).ravel()
        consts = LinReg(lr=0.05, precision="int8").stream_consts(sd)
        rows = np.asarray(qz.quantize_fixed_scale(
            X[flat], consts["x_scale"], 8).values)
        np.testing.assert_array_equal(
            np.asarray(host["X"]).reshape(-1, 5), rows)
