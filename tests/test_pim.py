"""PimGrid engine semantics: the virtual-DPU map-reduce must be exactly a
sum over row shards, padding must never leak, and the shard_map (mesh)
path must agree with the single-device path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pim import PimGrid, make_cpu_grid
from repro.launch.mesh import make_host_mesh


def test_shard_rows_pads_and_masks():
    grid = make_cpu_grid(8)
    X = jnp.arange(30, dtype=jnp.float32)[:, None]
    data, n = grid.shard_rows(X)
    assert n == 30
    assert data["X"].shape == (8, 4, 1)
    assert float(jnp.sum(data["w"])) == 30.0


def test_map_reduce_equals_direct_sum():
    grid = make_cpu_grid(16)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (1000, 5))
    data, n = grid.shard_rows(X)

    def local_fn(_, sl):
        return {"s": jnp.sum(sl["X"] * sl["w"][:, None], axis=0),
                "n": jnp.sum(sl["w"])}

    out = grid.map_reduce(local_fn, (), data)
    np.testing.assert_allclose(np.asarray(out["s"]),
                               np.asarray(jnp.sum(X, axis=0)), rtol=1e-5)
    assert float(out["n"]) == 1000.0


def test_vdpu_count_invariance():
    """Statistics must not depend on the grid size (paper scaling runs)."""
    key = jax.random.PRNGKey(1)
    X = jax.random.normal(key, (512, 3))

    def local_fn(_, sl):
        return jnp.sum(sl["X"] ** 2 * sl["w"][:, None])

    outs = []
    for v in (4, 16, 64):
        grid = make_cpu_grid(v)
        data, _ = grid.shard_rows(X)
        outs.append(float(grid.map_reduce(local_fn, (), data)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5)


def test_mesh_path_matches_single_device():
    mesh = make_host_mesh(1, 1)
    grid_m = PimGrid(n_vdpus=8, mesh=mesh, data_axes=("data",))
    grid_s = make_cpu_grid(8)
    X = jax.random.normal(jax.random.PRNGKey(2), (100, 4))

    def local_fn(w, sl):
        return sl["X"].T @ (sl["X"] @ w * sl["w"])

    w = jnp.ones((4,))
    d_m, _ = grid_m.shard_rows(X)
    d_s, _ = grid_s.shard_rows(X)
    out_m = grid_m.map_reduce(local_fn, w, d_m)
    out_s = grid_s.map_reduce(local_fn, w, d_s)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_s),
                               rtol=1e-5)


def test_fit_loop_runs_and_tracks_metrics():
    grid = make_cpu_grid(4)
    X = jax.random.normal(jax.random.PRNGKey(3), (64, 2))
    data, n = grid.shard_rows(X)

    def local_fn(w, sl):
        return {"g": jnp.sum(sl["X"] * sl["w"][:, None], axis=0)}

    def update_fn(w, merged):
        return w - 0.1 * merged["g"] / n, {"gnorm": jnp.sum(merged["g"]**2)}

    w, hist = grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                       update_fn=update_fn, data=data, steps=5)
    assert len(hist) == 5
    assert w.shape == (2,)


def test_grid_shard_count_properties():
    grid = make_cpu_grid(8)
    assert grid.n_shards == 1
    assert grid.data_sharding() is None
    mesh = make_host_mesh(1, 1)
    grid_m = PimGrid(n_vdpus=8, mesh=mesh, data_axes=("data",))
    assert grid_m.n_shards == 1
    assert grid_m.data_sharding() is not None
