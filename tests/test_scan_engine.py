"""The lax.scan step engine must be a drop-in for the seed's per-step
Python loop: bit-exact results, per-step metric streaming, and no
retracing across repeated fits with the same step-function signature."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import train_linreg, train_kmeans

KEY = jax.random.PRNGKey(0)


class TestScanVsPythonLoop:
    def test_linreg_fp32_bit_exact(self):
        X, y, _ = datasets.regression(KEY, 500, 8)
        grid = make_cpu_grid(8)
        r_scan = train_linreg(grid, X, y, lr=0.05, steps=70)
        r_py = train_linreg(grid, X, y, lr=0.05, steps=70,
                            engine="python")
        np.testing.assert_array_equal(np.asarray(r_scan.w),
                                      np.asarray(r_py.w))
        assert len(r_scan.history) == len(r_py.history) == 70
        np.testing.assert_array_equal(
            np.asarray(r_scan.history[-1]["loss"]),
            np.asarray(r_py.history[-1]["loss"]))

    def test_kmeans_fp32_bit_exact(self):
        X, _, _ = datasets.blobs(KEY, 600, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r_scan = train_kmeans(grid, X, 3, iters=9)
        r_py = train_kmeans(grid, X, 3, iters=9, engine="python")
        np.testing.assert_array_equal(np.asarray(r_scan.centroids),
                                      np.asarray(r_py.centroids))
        np.testing.assert_array_equal(
            np.asarray(r_scan.history[-1]["sse"]),
            np.asarray(r_py.history[-1]["sse"]))

    def test_unknown_engine_raises(self):
        grid = make_cpu_grid(4)
        X = jnp.zeros((8, 2))
        data, n = grid.shard_rows(X)
        with pytest.raises(ValueError):
            grid.fit(init_state=jnp.zeros((2,)),
                     local_fn=lambda w, sl: {"g": jnp.zeros((2,))},
                     update_fn=lambda w, m: (w, {}),
                     data=data, steps=1, engine="bogus")

    def test_nonpositive_scan_chunk_raises(self):
        grid = make_cpu_grid(4)
        X = jnp.zeros((8, 2))
        data, n = grid.shard_rows(X)
        with pytest.raises(ValueError):
            grid.fit(init_state=jnp.zeros((2,)),
                     local_fn=lambda w, sl: {"g": jnp.zeros((2,))},
                     update_fn=lambda w, m: (w, {}),
                     data=data, steps=4, scan_chunk=0)


class TestChunking:
    def _setup(self, grid):
        X = jnp.arange(64, dtype=jnp.float32).reshape(32, 2)
        data, n = grid.shard_rows(X)

        def local_fn(w, sl):
            return {"g": jnp.sum(sl["X"] * sl["w"][:, None], axis=0)}

        def update_fn(w, merged):
            return w - 0.01 * merged["g"] / n, {"gn": jnp.sum(merged["g"])}

        return data, local_fn, update_fn

    def test_steps_not_multiple_of_chunk(self):
        grid = make_cpu_grid(4)
        data, local_fn, update_fn = self._setup(grid)
        w, hist = grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                           update_fn=update_fn, data=data, steps=11,
                           scan_chunk=4)
        assert len(hist) == 11
        # same as one big chunk
        w2, _ = grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                         update_fn=update_fn, data=data, steps=11,
                         scan_chunk=64)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))

    def test_callback_streams_every_step(self):
        grid = make_cpu_grid(4)
        data, local_fn, update_fn = self._setup(grid)
        seen = []
        grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                 update_fn=update_fn, data=data, steps=10, scan_chunk=3,
                 callback=lambda s, state, m: seen.append(s))
        assert seen == list(range(10))

    def test_zero_steps(self):
        grid = make_cpu_grid(4)
        data, local_fn, update_fn = self._setup(grid)
        w0 = jnp.ones((2,))
        w, hist = grid.fit(init_state=w0, local_fn=local_fn,
                           update_fn=update_fn, data=data, steps=0)
        assert hist == []
        np.testing.assert_array_equal(np.asarray(w), np.asarray(w0))


class TestCompileCache:
    def test_repeat_fit_does_not_retrace(self):
        """Repeated fits with the same step-fn signature share one jitted
        runner and its traces (the paper's loop re-runs constantly;
        retracing would dominate)."""
        grid = make_cpu_grid(4)
        X = jax.random.normal(KEY, (64, 3))
        data, n = grid.shard_rows(X)

        def local_fn(w, sl):
            return {"g": sl["X"].T @ (sl["X"] @ w * sl["w"])}

        def update_fn(w, merged):
            return w - 0.01 * merged["g"] / n, {}

        runner = grid.make_runner(local_fn, update_fn)
        w0 = jnp.zeros((3,))
        for _ in range(3):
            grid.fit(init_state=w0, local_fn=local_fn,
                     update_fn=update_fn, data=data, steps=40,
                     scan_chunk=32)
        # same runner object is served for the same closures…
        assert grid.make_runner(local_fn, update_fn) is runner
        # …and it compiled at most the two chunk lengths (32 and 8)
        assert runner._cache_size() <= 2

    def test_compiled_step_deprecated_alias(self):
        """The pre-cadence name still works but warns, pointing at
        make_runner (scheduled for removal)."""
        grid = make_cpu_grid(4)

        def local_fn(w, sl):
            return {"g": jnp.sum(sl["X"], axis=0)}

        def update_fn(w, merged):
            return w - merged["g"], {}

        with pytest.warns(DeprecationWarning, match="make_runner"):
            runner = grid.compiled_step(local_fn, update_fn)
        # the alias still serves the same cached runner
        assert grid.make_runner(local_fn, update_fn) is runner

    def test_same_code_different_closures_share_runner(self):
        """train_* re-creates its closures each call; signature keying
        must still hit."""
        grid = make_cpu_grid(4)
        X, y, _ = datasets.regression(KEY, 200, 4)
        train_linreg(grid, X, y, lr=0.1, steps=5)
        n_before = len(grid._fit_cache)
        train_linreg(grid, X, y, lr=0.1, steps=5)
        assert len(grid._fit_cache) == n_before

    def test_different_hyperparams_do_not_collide(self):
        """Closures capturing different primitive values must get their
        own runner (lr is baked into the trace as a constant)."""
        grid = make_cpu_grid(4)
        X, y, _ = datasets.regression(KEY, 200, 4)
        r1 = train_linreg(grid, X, y, lr=0.1, steps=30)
        r2 = train_linreg(grid, X, y, lr=0.01, steps=30)
        assert float(jnp.max(jnp.abs(r1.w - r2.w))) > 1e-6

    def test_default_arg_hyperparams_do_not_collide(self):
        """Hyperparameters bound through default args (not closure
        cells) must also distinguish cache keys."""
        grid = make_cpu_grid(4)
        X = jnp.ones((16, 2))
        data, n = grid.shard_rows(X)

        def local_fn(w, sl):
            return {"g": jnp.sum(sl["X"] * sl["w"][:, None], axis=0)}

        def make_update(lr):
            def update_fn(w, merged, lr=lr):
                return w - lr * merged["g"] / n, {}
            return update_fn

        w1, _ = grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                         update_fn=make_update(0.1), data=data, steps=3)
        w2, _ = grid.fit(init_state=jnp.zeros((2,)), local_fn=local_fn,
                         update_fn=make_update(0.01), data=data, steps=3)
        assert float(jnp.max(jnp.abs(w1 - w2))) > 1e-8
