"""The overlapped + compressed merge pipeline
(``fit(overlap_merge=..., merge_compression=...)``).

Contracts pinned here:
  * both flags off reproduces the PR 2 engine bit-exactly for all four
    mlalgos (it takes the unmodified code path),
  * int8 + error-feedback merges match a hand-rolled numpy oracle and
    converge to within rtol of exact merges over 200 steps,
  * the overlap pipeline (one-round staleness) matches its python-engine
    oracle bit-exactly and converges within tolerance,
  * integer-dtype leaves pass through the compressed reduce uncompressed,
  * the error-feedback buffer continues across ``fit`` calls via the
    ``merge_state`` holder and across Trainer restarts via checkpoints.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import datasets, make_cpu_grid
from repro.core.mlalgos import (make_linreg_step, train_linreg,
                                train_logreg, train_kmeans, train_dtree)
from repro.core.mlalgos.linreg import closed_form
from repro.distributed import compression as comp
from repro.distributed.compression import CompressionConfig
from repro.runtime import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)
INT8 = CompressionConfig(bits=8)


class TestDefaultsBitExact:
    """overlap_merge=False + merge_compression=None must be the PR 2
    engine — asserted against the python-loop oracle for all four
    mlalgos, with the flags passed explicitly."""

    def test_linreg(self):
        X, y, _ = datasets.regression(KEY, 400, 8)
        grid = make_cpu_grid(8)
        r = train_linreg(grid, X, y, lr=0.05, steps=40,
                         overlap_merge=False, merge_compression=None)
        r_py = train_linreg(grid, X, y, lr=0.05, steps=40,
                            engine="python")
        np.testing.assert_array_equal(np.asarray(r.w), np.asarray(r_py.w))

    def test_logreg(self):
        X, y, _ = datasets.binary_classification(KEY, 400, 6)
        grid = make_cpu_grid(8)
        r = train_logreg(grid, X, y, lr=0.5, steps=30,
                         overlap_merge=False, merge_compression=None)
        r_py = train_logreg(grid, X, y, lr=0.5, steps=30,
                            engine="python")
        np.testing.assert_array_equal(np.asarray(r.w), np.asarray(r_py.w))

    def test_kmeans(self):
        X, _, _ = datasets.blobs(KEY, 500, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r = train_kmeans(grid, X, 3, iters=8, overlap_merge=False,
                         merge_compression=None)
        r_py = train_kmeans(grid, X, 3, iters=8, engine="python")
        np.testing.assert_array_equal(np.asarray(r.centroids),
                                      np.asarray(r_py.centroids))

    def test_dtree_flags_inert(self):
        X, y = datasets.mixture_classification(KEY, 600, 6, 2)
        grid = make_cpu_grid(8)
        r0 = train_dtree(grid, X, y, max_depth=3)
        r1 = train_dtree(grid, X, y, max_depth=3, overlap_merge=True,
                         merge_compression=INT8)
        np.testing.assert_array_equal(np.asarray(r0.tree.feature),
                                      np.asarray(r1.tree.feature))
        np.testing.assert_array_equal(np.asarray(r0.tree.threshold),
                                      np.asarray(r1.tree.threshold))

    def test_cadence_with_flags_off_bit_exact(self):
        X, y, _ = datasets.regression(KEY, 300, 5)
        grid = make_cpu_grid(4)
        r = train_linreg(grid, X, y, lr=0.05, steps=12, merge_every=4,
                         overlap_merge=False, merge_compression=None)
        r_py = train_linreg(grid, X, y, lr=0.05, steps=12, merge_every=4,
                            engine="python")
        np.testing.assert_array_equal(np.asarray(r.w), np.asarray(r_py.w))


def _ef_quantize_np(target, bits=8):
    qmax = 2 ** (bits - 1) - 1
    amax = np.max(np.abs(target))
    scale = max(amax, 1e-12) / qmax
    q = np.clip(np.round(target / scale), -qmax - 1, qmax)
    deq = (q * scale).astype(np.float32)
    return deq, target - deq


class TestEFConvergenceOracle:
    """int8 + error feedback on the cadence-1 gradient wire: a numpy
    replica of the quantized merge converges to within rtol of exact
    merges over 200 steps, and the jax engine matches the replica."""

    def _setup(self):
        V, per, d, lr = 4, 32, 6, 0.05
        X = np.asarray(jax.random.normal(KEY, (V * per, d)), np.float32)
        w_true = np.linspace(-1.0, 1.0, d).astype(np.float32)
        y = X @ w_true
        return V, per, d, lr, X, y

    def _oracle(self, V, per, d, lr, X, y, steps, compressed):
        n = V * per
        w = np.zeros((d,), np.float32)
        e_g = np.zeros((d,), np.float32)
        e_l = np.zeros((), np.float32)
        for _ in range(steps):
            g = np.zeros((d,), np.float32)
            loss = np.float32(0.0)
            for v in range(V):
                Xv, yv = X[v * per:(v + 1) * per], y[v * per:(v + 1) * per]
                r = Xv @ w - yv
                g += (Xv.T @ r).astype(np.float32)
                loss += np.float32(np.sum(r * r))
            if compressed:
                g, e_g = _ef_quantize_np(g + e_g)
                loss, e_l = _ef_quantize_np(loss + e_l)
            w = w - lr * g / n
        return w

    def test_int8_ef_converges_within_rtol_of_exact(self):
        V, per, d, lr, X, y = self._setup()
        steps = 200
        w_exact = self._oracle(V, per, d, lr, X, y, steps, False)
        w_comp = self._oracle(V, per, d, lr, X, y, steps, True)
        # error feedback keeps the compressed iterates O(1) from exact
        np.testing.assert_allclose(w_comp, w_exact, rtol=5e-3, atol=5e-3)

    def test_engine_matches_numpy_oracle(self):
        V, per, d, lr, X, y = self._setup()
        steps = 200
        grid = make_cpu_grid(V)
        res = train_linreg(grid, jnp.asarray(X), jnp.asarray(y), lr=lr,
                           steps=steps, merge_compression=INT8)
        w_oracle = self._oracle(V, per, d, lr, X, y, steps, True)
        np.testing.assert_allclose(np.asarray(res.w), w_oracle,
                                   rtol=1e-3, atol=1e-3)

    def test_engine_compressed_close_to_engine_exact(self):
        V, per, d, lr, X, y = self._setup()
        grid = make_cpu_grid(V)
        r_exact = train_linreg(grid, jnp.asarray(X), jnp.asarray(y),
                               lr=lr, steps=200)
        r_comp = train_linreg(grid, jnp.asarray(X), jnp.asarray(y),
                              lr=lr, steps=200, merge_compression=INT8)
        np.testing.assert_allclose(np.asarray(r_comp.w),
                                   np.asarray(r_exact.w),
                                   rtol=5e-3, atol=5e-3)

    def test_no_error_feedback_biases_more(self):
        """EF exists for a reason: with it the compressed run lands
        closer to exact than without it (stateless quantization)."""
        V, per, d, lr, X, y = self._setup()
        grid = make_cpu_grid(V)
        w_exact = np.asarray(train_linreg(
            grid, jnp.asarray(X), jnp.asarray(y), lr=lr, steps=200).w)
        w_ef = np.asarray(train_linreg(
            grid, jnp.asarray(X), jnp.asarray(y), lr=lr, steps=200,
            merge_compression=INT8).w)
        w_noef = np.asarray(train_linreg(
            grid, jnp.asarray(X), jnp.asarray(y), lr=lr, steps=200,
            merge_compression=CompressionConfig(
                bits=8, error_feedback=False)).w)
        assert np.linalg.norm(w_ef - w_exact) <= \
            np.linalg.norm(w_noef - w_exact) + 1e-6


class TestOverlapPipeline:
    def test_scan_matches_python_engine(self):
        X, y, _ = datasets.regression(KEY, 400, 8)
        grid = make_cpu_grid(8)
        for kwargs in ({"overlap_merge": True},
                       {"overlap_merge": True, "merge_compression": INT8},
                       {"merge_compression": INT8},
                       {"overlap_merge": True, "merge_every": 4},
                       {"merge_compression": INT8, "merge_every": 5}):
            r_scan = train_linreg(grid, X, y, lr=0.05, steps=24,
                                  **kwargs)
            r_py = train_linreg(grid, X, y, lr=0.05, steps=24,
                                engine="python", **kwargs)
            np.testing.assert_array_equal(
                np.asarray(r_scan.w), np.asarray(r_py.w)), kwargs
            assert len(r_scan.history) == len(r_py.history) == 24

    def test_overlap_converges_within_tolerance(self):
        """One round of staleness must not derail convergence at
        cadence 1 (classic pipelined SGD)."""
        X, y, _ = datasets.regression(KEY, 800, 8)
        w_star = np.asarray(closed_form(X, y))
        grid = make_cpu_grid(8)
        errs = {}
        for ovl in (False, True):
            res = train_linreg(grid, X, y, lr=0.05, steps=200,
                               overlap_merge=ovl)
            errs[ovl] = float(np.linalg.norm(np.asarray(res.w) - w_star))
        assert errs[True] <= 1.5 * errs[False] + 0.05, errs

    def test_overlap_history_and_callbacks_aligned(self):
        X, y, _ = datasets.regression(KEY, 200, 4)
        grid = make_cpu_grid(4)
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)
        seen = []
        _, hist = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                           data=data, steps=10, overlap_merge=True,
                           callback=lambda s, st, m: seen.append(s))
        assert seen == list(range(10))
        assert len(hist) == 10

    def test_overlap_cadence_first_round_metrics_match_exact(self):
        """Round 1 of the cadence-k pipeline is the same phase from the
        same state as the exact engine's round 1 — its reported metrics
        must match."""
        X, y, _ = datasets.regression(KEY, 240, 5)
        grid = make_cpu_grid(4)
        r_ovl = train_linreg(grid, X, y, lr=0.05, steps=12,
                             merge_every=4, overlap_merge=True)
        r_base = train_linreg(grid, X, y, lr=0.05, steps=12,
                              merge_every=4)
        for j in range(4):
            np.testing.assert_allclose(
                float(r_ovl.history[j]["loss"]),
                float(r_base.history[j]["loss"]), rtol=1e-6)

    def test_overlap_cadence_rounds_all_distinct(self):
        """Regression: a replacement commit decoupled the scan into two
        identical half-rate chains — every phase ran twice and history
        repeated in k-step blocks.  The delayed-delta commit keeps one
        chain: consecutive round blocks must differ and the anchor must
        advance every round."""
        X, y, _ = datasets.regression(KEY, 320, 6)
        grid = make_cpu_grid(4)
        k, rounds = 4, 6
        r = train_linreg(grid, X, y, lr=0.05, steps=k * rounds,
                         merge_every=k, overlap_merge=True)
        blocks = [tuple(float(r.history[i * k + j]["loss"])
                        for j in range(k)) for i in range(rounds)]
        for a, b in zip(blocks, blocks[1:]):
            assert a != b, blocks

    def test_overlap_cadence_keeps_full_progress_rate(self):
        """The pipelined cadence engine must track the exact engine's
        progress (staleness delays it by ~one round, it must not halve
        it: L rounds of overlap >= L-2 rounds of exact progress)."""
        X, y, _ = datasets.regression(KEY, 800, 8)
        w_star = np.asarray(closed_form(X, y))
        grid = make_cpu_grid(8)
        k, rounds = 4, 15
        err_ovl = float(np.linalg.norm(np.asarray(train_linreg(
            grid, X, y, lr=0.05, steps=k * rounds, merge_every=k,
            overlap_merge=True).w) - w_star))
        err_lag = float(np.linalg.norm(np.asarray(train_linreg(
            grid, X, y, lr=0.05, steps=k * (rounds - 2),
            merge_every=k).w) - w_star))
        assert err_ovl <= err_lag * 1.2 + 1e-4, (err_ovl, err_lag)

    def test_overlap_kmeans_converges(self):
        X, _, _ = datasets.blobs(KEY, 600, 4, k=3, spread=0.3)
        grid = make_cpu_grid(8)
        r_base = train_kmeans(grid, X, 3, iters=12)
        r_ovl = train_kmeans(grid, X, 3, iters=12, overlap_merge=True,
                             merge_compression=INT8)
        sse_base = float(r_base.history[-1]["sse"])
        sse_ovl = float(r_ovl.history[-1]["sse"])
        assert sse_ovl <= 1.2 * sse_base + 1e-3, (sse_base, sse_ovl)


class TestIntegerLeafPassthrough:
    def test_compressed_reduce_int_leaves_exact(self):
        """int32 histogram/count leaves must take the exact psum, not a
        quantizer that treats them as fp32 (regression: int32 counts
        were quantized and came back corrupted)."""
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, 1)
        grads = {"hist": jnp.arange(300, dtype=jnp.int32),
                 "g": jnp.linspace(-3.0, 7.0, 64)}
        err = comp.init_error_state(grads)
        cfg = CompressionConfig(bits=8, slow_axis="data", fast_axes=())

        def body(g, e):
            return comp.compressed_reduce(g, e, cfg)

        specs = jax.tree.map(lambda _: P(), grads)
        out, new_err = shard_map(
            body, mesh=mesh, in_specs=(specs, specs),
            out_specs=(specs, specs), check_rep=False)(grads, err)
        # integer leaf: bit-exact, no error accumulated
        np.testing.assert_array_equal(np.asarray(out["hist"]),
                                      np.arange(300, dtype=np.int32))
        assert out["hist"].dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(new_err["hist"]), 0)
        # float leaf: quantized round-trip with residual accounted
        np.testing.assert_allclose(
            np.asarray(out["g"] + new_err["g"]),
            np.asarray(grads["g"]), atol=1e-6)

    def test_ef_compress_tree_int_passthrough(self):
        tree = {"counts": jnp.asarray([5, 0, 9], jnp.int32),
                "sums": jnp.asarray([1.5, -2.25, 0.125])}
        err = comp.init_error_state(tree)
        out, new_err = comp.ef_compress_tree(tree, err, INT8)
        np.testing.assert_array_equal(np.asarray(out["counts"]),
                                      [5, 0, 9])
        np.testing.assert_allclose(
            np.asarray(out["sums"] + new_err["sums"]),
            np.asarray(tree["sums"]), atol=1e-6)

    def test_degenerate_bits_rejected(self):
        """bits=1 would make qmax = 0 and the quantizer divide by zero
        (silent NaN state); the config rejects it at construction."""
        for bits in (0, 1, 17, -8):
            with pytest.raises(ValueError, match="bits"):
                CompressionConfig(bits=bits)
        CompressionConfig(bits=2)          # narrowest legal width

    def test_wire_bytes(self):
        tree = {"g": jnp.zeros((100,), jnp.float32),
                "hist": jnp.zeros((10,), jnp.int32)}
        assert comp.wire_bytes(tree, None) == 100 * 4 + 10 * 4
        # float leaf: 1 byte/elem + 4-byte scale; int leaf: native width
        assert comp.wire_bytes(tree, INT8) == 100 + 4 + 10 * 4


class TestMergeStateContinuation:
    def test_ef_continues_across_fit_calls(self):
        X, y, _ = datasets.regression(KEY, 320, 6)
        grid = make_cpu_grid(4)
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)

        w_one, _ = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                            data=data, steps=100,
                            merge_compression=INT8)
        holder: dict = {}
        w_half, _ = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                             data=data, steps=50,
                             merge_compression=INT8, merge_state=holder)
        assert "error" in holder
        w_two, _ = grid.fit(init_state=w_half, local_fn=lf, update_fn=uf,
                            data=data, steps=50,
                            merge_compression=INT8, merge_state=holder)
        np.testing.assert_allclose(np.asarray(w_two), np.asarray(w_one),
                                   rtol=1e-6, atol=1e-7)

    def test_dropping_ef_between_fits_diverges(self):
        """The holder exists because the residual matters: restarting
        with a zero buffer mid-run gives a (slightly) different
        trajectory than the continuous one."""
        X, y, _ = datasets.regression(KEY, 320, 6)
        grid = make_cpu_grid(4)
        data, n, lf, uf, w0 = make_linreg_step(grid, X, y, lr=0.05)
        holder: dict = {}
        w_half, _ = grid.fit(init_state=w0, local_fn=lf, update_fn=uf,
                             data=data, steps=50,
                             merge_compression=INT8, merge_state=holder)
        w_cont, _ = grid.fit(init_state=w_half, local_fn=lf,
                             update_fn=uf, data=data, steps=50,
                             merge_compression=INT8, merge_state=holder)
        w_drop, _ = grid.fit(init_state=w_half, local_fn=lf,
                             update_fn=uf, data=data, steps=50,
                             merge_compression=INT8)
        assert not np.array_equal(np.asarray(w_cont), np.asarray(w_drop))


class TestTrainerCheckpointsEF:
    def _pieces(self, tmp_path, holder):
        def step_fn(state, batch):
            w = state["w"] - 0.1 * batch["g"]
            return {"w": w}, {"loss": jnp.sum(w ** 2)}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                            log_every=100, merge_compression=INT8)
        return Trainer(step_fn, {"w": jnp.ones((3,))},
                       lambda s: {"g": jnp.ones((3,))}, cfg,
                       merge_state=holder)

    def test_ef_buffer_round_trips_through_checkpoint(self, tmp_path):
        holder = {"error": {"g": jnp.asarray([0.5, -0.25, 0.0])}}
        tr = self._pieces(tmp_path, holder)
        tr.run(10)
        # resume: a fresh holder gets the checkpointed residual back
        holder2 = {"error": {"g": jnp.zeros((3,))}}
        tr2 = self._pieces(tmp_path, holder2)
        assert tr2.start_step == 10
        np.testing.assert_allclose(np.asarray(holder2["error"]["g"]),
                                   np.asarray(holder["error"]["g"]))
        np.testing.assert_allclose(np.asarray(tr2.state["w"]),
                                   np.asarray(tr.state["w"]))

    def test_compression_mismatch_refuses_resume(self, tmp_path):
        holder = {"error": {"g": jnp.zeros((3,))}}
        tr = self._pieces(tmp_path, holder)
        tr.run(10)

        def step_fn(state, batch):
            return state, {"loss": jnp.zeros(())}

        bad_cfg = TrainerConfig(
            ckpt_dir=str(tmp_path),
            merge_compression=CompressionConfig(bits=4))
        with pytest.raises(ValueError, match="compression"):
            Trainer(step_fn, {"w": jnp.ones((3,))}, lambda s: {},
                    bad_cfg, merge_state={"error": {"g": jnp.zeros((3,))}})

    def test_unseeded_holder_resume_gives_clear_error(self, tmp_path):
        """A restarting process with an empty holder meeting a
        compressed checkpoint must get told to seed the buffer, not a
        structure-mismatch crash."""
        holder = {"error": {"g": jnp.zeros((3,))}}
        tr = self._pieces(tmp_path, holder)
        tr.run(10)
        with pytest.raises(ValueError, match="init_merge_error"):
            self._pieces(tmp_path, {})

    def test_seeded_holder_resumes_bare_checkpoint(self, tmp_path):
        """Migration path: enabling compression over a run whose
        checkpoints predate it restores the model and keeps the seeded
        buffer as the fresh residual."""
        def step_fn(state, batch):
            return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

        bare_cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5)
        tr = Trainer(step_fn, {"w": jnp.ones((2,))}, lambda s: {},
                     bare_cfg)
        tr.run(10)
        seeded = {"error": {"g": jnp.asarray([0.5, 0.5])}}
        cfg = TrainerConfig(ckpt_dir=str(tmp_path),
                            merge_compression=INT8)
        tr2 = Trainer(step_fn, {"w": jnp.ones((2,))}, lambda s: {},
                      cfg, merge_state=seeded)
        assert tr2.start_step == 10
        np.testing.assert_allclose(np.asarray(tr2.state["w"]),
                                   np.asarray(tr.state["w"]))
        np.testing.assert_allclose(np.asarray(seeded["error"]["g"]),
                                   [0.5, 0.5])

    def test_midrun_recovery_through_bare_checkpoint(self, tmp_path):
        """Regression: fault recovery must use the same layout-robust
        restore as construction — a seeded run resumed over bare
        pre-compression checkpoints must replay through them, not crash
        on a template mismatch."""
        def ok_step(state, batch):
            return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

        bare_cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                 log_every=1)
        Trainer(ok_step, {"w": jnp.ones((2,))}, lambda s: {},
                bare_cfg).run(6)

        calls = {"n": 0}

        def flaky_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 2:          # fail once mid-run post-resume
                raise RuntimeError("injected fault")
            return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                            log_every=1, merge_compression=INT8)
        tr = Trainer(flaky_step, {"w": jnp.ones((2,))}, lambda s: {},
                     cfg, merge_state={"error": {"g": jnp.zeros((2,))}})
        out = tr.run(4)                  # recovers and completes
        assert out["restarts"] == 1

    def test_genuine_structure_mismatch_not_misdiagnosed(self, tmp_path):
        """Regression: a bare-vs-bare structure mismatch must surface as
        such — not as advice to seed a merge_state buffer."""
        def step_fn(state, batch):
            return state, {"loss": jnp.zeros(())}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path))
        Trainer(step_fn, {"w": jnp.ones((2,))}, lambda s: {}, cfg).run(3)
        with pytest.raises(ValueError, match="structure mismatch"):
            Trainer(step_fn, {"renamed": jnp.ones((2,))}, lambda s: {},
                    cfg)

    def test_bare_state_checkpoints_unchanged(self, tmp_path):
        """No holder -> PR 2 checkpoint layout (backward compatible)."""
        def step_fn(state, batch):
            return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

        cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
        tr = Trainer(step_fn, {"w": jnp.ones(())}, lambda s: {}, cfg)
        tr.run(3)
        tr2 = Trainer(step_fn, {"w": jnp.ones(())}, lambda s: {}, cfg)
        assert tr2.start_step == 3
        np.testing.assert_allclose(float(tr2.state["w"]),
                                   float(tr.state["w"]))


class TestMergeOverlapReport:
    def test_sync_interleaving_detected(self):
        from repro.roofline import analysis as ra
        hlo = """
HloModule m

%body (p: (f32[4], f32[4])) -> (f32[4], f32[4]) {
  %p = (f32[4]{0}, f32[4]{0}) parameter(0)
  %g0 = f32[4]{0} get-tuple-element(%p), index=0
  %g1 = f32[4]{0} get-tuple-element(%p), index=1
  %ar = f32[4]{0} all-reduce(%g1), replica_groups={{0,1}}, to_apply=%add
  %d = f32[4]{0} dot(%g0, %g0), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT %t = (f32[4]{0}, f32[4]{0}) tuple(%ar, %d)
}

%cond (p: (f32[4], f32[4])) -> pred[] {
  %p = (f32[4]{0}, f32[4]{0}) parameter(0)
  %c = s32[] constant(8)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (a: f32[4]) -> (f32[4], f32[4]) {
  %a = f32[4]{0} parameter(0)
  %t0 = (f32[4]{0}, f32[4]{0}) tuple(%a, %a)
  ROOT %w = (f32[4]{0}, f32[4]{0}) while(%t0), condition=%cond, body=%body
}
"""
        rep = ra.merge_overlap_report(hlo)
        assert rep["while_bodies"] == 1
        assert rep["sync_all_reduces"] == 1
        assert rep["dots_after_sync_all_reduce"] == 1
        assert rep["overlapped"] is True

    def test_serial_schedule_not_overlapped(self):
        from repro.roofline import analysis as ra
        hlo = """
HloModule m

%body (p: (f32[4], f32[4])) -> (f32[4], f32[4]) {
  %p = (f32[4]{0}, f32[4]{0}) parameter(0)
  %g0 = f32[4]{0} get-tuple-element(%p), index=0
  %d = f32[4]{0} dot(%g0, %g0), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %ar = f32[4]{0} all-reduce(%d), replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (f32[4]{0}, f32[4]{0}) tuple(%ar, %d)
}

ENTRY %main (a: f32[4]) -> (f32[4], f32[4]) {
  %a = f32[4]{0} parameter(0)
  %t0 = (f32[4]{0}, f32[4]{0}) tuple(%a, %a)
  ROOT %w = (f32[4]{0}, f32[4]{0}) while(%t0), condition=%cond, body=%body
}
"""
        rep = ra.merge_overlap_report(hlo)
        assert rep["overlapped"] is False
